//! One SSR slot: shadowed configuration, the data movers, and the mode-
//! specific address generation datapaths of Fig. 1a/1b.
//!
//! A unit owns **one** memory port (§2.2): the index and data channels of
//! indirection/match/egress modes share it through a round-robin-ish
//! arbiter with data priority, which is what imposes the n/(n+1) peak
//! data-mover utilization the paper derives (67 %, 80 %, 88.9 % for
//! 32/16/8-bit indices on a 64-bit bus).

use std::collections::VecDeque;

use crate::sim::isa::SsrField;
use crate::sim::tcdm::{Access, Tcdm};

use super::{AffineCfg, AffineGen, DataCmd, JobCfg, Mode, CMD_FIFO_DEPTH, DATA_FIFO_DEPTH, IDX_FIFO_DEPTH};

/// Raw shadow configuration registers (written by `scfgw`, §3: "shadowed
/// configuration registers enable the setup of a new stream while another
/// is still running").
#[derive(Clone, Copy, Debug)]
pub struct ShadowCfg {
    pub data_base: u64,
    pub bounds: [u64; 4],
    pub strides: [i64; 4],
    pub idx_base: u64,
    pub idx_len: u64,
    pub idx_size: u8,
    pub idx_shift: u8,
}

impl Default for ShadowCfg {
    fn default() -> Self {
        // Upper affine bounds reset to 1 so a plain 1D job only needs
        // Bound0/Stride0 configured (matches the hardware reset values).
        ShadowCfg {
            data_base: 0,
            bounds: [1; 4],
            strides: [0; 4],
            idx_base: 0,
            idx_len: 0,
            idx_size: 0,
            idx_shift: 0,
        }
    }
}

impl ShadowCfg {
    fn job(&self, mode: Mode) -> JobCfg {
        JobCfg {
            mode,
            affine: AffineCfg {
                base: self.data_base,
                bounds: self.bounds,
                strides: self.strides,
            },
            idx_base: self.idx_base,
            idx_len: self.idx_len,
            idx_size: self.idx_size,
            idx_shift: self.idx_shift,
        }
    }
}

/// Walks the index array word-by-word, honoring arbitrary base alignment
/// (§2.1.1: the index serializer extracts indices of the configured size
/// from buffered index *words*, fully utilizing the memory bus).
#[derive(Clone, Debug)]
struct IdxFetcher {
    base: u64,
    len: u64,
    size_log2: u8,
    /// Next index ordinal to fetch.
    next_k: u64,
}

impl IdxFetcher {
    fn new(cfg: &JobCfg) -> Self {
        IdxFetcher { base: cfg.idx_base, len: cfg.idx_len, size_log2: cfg.idx_size, next_k: 0 }
    }

    fn done(&self) -> bool {
        self.next_k >= self.len
    }

    /// The (word-aligned address, first ordinal, count) of the next index
    /// word to fetch.
    fn next_word(&self) -> Option<(u64, u64, u64)> {
        if self.done() {
            return None;
        }
        let ib = 1u64 << self.size_log2;
        let first_addr = self.base + self.next_k * ib;
        let word_addr = first_addr & !7;
        let word_end = word_addr + 8;
        let fit = (word_end - first_addr) / ib;
        let count = fit.min(self.len - self.next_k);
        Some((word_addr, self.next_k, count))
    }

    /// Extract `count` indices starting at ordinal `first_k` from the
    /// fetched 64-bit `word`.
    fn serialize(&mut self, word: u64, word_addr: u64, first_k: u64, count: u64, out: &mut VecDeque<u64>) {
        let ib = 1u64 << self.size_log2;
        let bits = 8 * ib;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        for i in 0..count {
            let byte_off = self.base + (first_k + i) * ib - word_addr;
            out.push_back((word >> (8 * byte_off)) & mask);
        }
        self.next_k = first_k + count;
    }
}

/// Live state of a running job.
#[derive(Debug)]
pub struct ActiveJob {
    pub cfg: JobCfg,
    /// Data-address generator (affine modes), or egress/match data
    /// position counter wrapped as a linear generator.
    gen: AffineGen,
    idx_fetch: IdxFetcher,
    /// Serialized indices awaiting use (indirection) or comparator
    /// consumption (match modes).
    pub idx_fifo: VecDeque<u64>,
    /// Indices the comparator has consumed.
    pub idx_consumed: u64,
    /// Value-datapath commands from the comparator (match modes).
    pub cmd_fifo: VecDeque<DataCmd>,
    /// Current value position within the fiber (match modes).
    val_pos: u64,
    /// Data elements completed (fetched / written / zero-injected).
    pub elems_done: u64,
    /// Comparator signaled the end of the joint stream.
    pub end_seen: bool,
    /// ---- egress only ----
    /// Joint indices received from the comparator, awaiting coalescing.
    pub idx_in: VecDeque<u64>,
    /// Total joint indices received (== expected data elements).
    pub joint_received: u64,
    coalesce_buf: u64,
    coalesce_n: u64,
    idx_words_written: u64,
    idx_written: u64,
    /// Joint stream length (valid once the job is done).
    pub strctl_len: u64,
}

impl ActiveJob {
    fn new(cfg: JobCfg) -> Self {
        let gen = match cfg.mode {
            Mode::AffineRead | Mode::AffineWrite => AffineGen::new(cfg.affine),
            // Indirect modes consume one data element per index; match and
            // egress modes advance positions explicitly — give them a
            // linear generator over the value array for address book-
            // keeping where useful.
            _ => AffineGen::new(AffineCfg::linear(cfg.affine.base, u64::MAX, 8)),
        };
        ActiveJob {
            idx_fetch: IdxFetcher::new(&cfg),
            cfg,
            gen,
            idx_fifo: VecDeque::new(),
            idx_consumed: 0,
            cmd_fifo: VecDeque::new(),
            val_pos: 0,
            elems_done: 0,
            end_seen: false,
            idx_in: VecDeque::new(),
            joint_received: 0,
            coalesce_buf: 0,
            coalesce_n: 0,
            idx_words_written: 0,
            idx_written: 0,
            strctl_len: 0,
        }
    }

    /// All indices of this fiber have been handed to the comparator.
    pub fn match_exhausted(&self) -> bool {
        self.idx_consumed >= self.cfg.idx_len
    }

    /// Cancel remaining index processing (intersection early-out once the
    /// co-operand is exhausted: no further matches are possible).
    pub fn cancel_match_remaining(&mut self) {
        self.idx_consumed = self.cfg.idx_len;
        self.idx_fetch.next_k = self.cfg.idx_len;
        self.idx_fifo.clear();
    }

    fn finished(&self) -> bool {
        match self.cfg.mode {
            Mode::AffineRead | Mode::AffineWrite => self.gen.done(),
            Mode::IndirectRead | Mode::IndirectWrite => self.elems_done >= self.cfg.idx_len,
            Mode::Intersect | Mode::Union => self.end_seen && self.cmd_fifo.is_empty(),
            // Structure-only union has no value datapath to drain.
            Mode::UnionIdx => self.end_seen,
            Mode::Egress => {
                self.end_seen
                    && self.elems_done >= self.joint_received
                    && self.idx_written >= self.joint_received
                    && self.coalesce_n == 0
            }
            Mode::EgressIdx => {
                self.end_seen && self.idx_written >= self.joint_received && self.coalesce_n == 0
            }
        }
    }
}

/// One SSR slot of the streamer.
pub struct SsrUnit {
    pub slot: usize,
    shadow: ShadowCfg,
    pending: Option<JobCfg>,
    pub active: Option<ActiveJob>,
    /// Read-direction data FIFO (memory -> FPU register).
    pub data_fifo: VecDeque<f64>,
    /// Write-direction data FIFO (FPU register -> memory).
    pub wdata_fifo: VecDeque<f64>,
    /// Joint-stream length of the most recently *completed* job
    /// (`scfgr strctl_len`, Listing 4).
    pub last_strctl_len: u64,
    // ---- statistics ----
    pub mem_reads: u64,
    pub mem_writes: u64,
    pub idx_word_fetches: u64,
    pub zero_injections: u64,
    /// Cycles ticked with a job active on this lane (occupancy).
    pub busy_cycles: u64,
}

impl SsrUnit {
    pub fn new(slot: usize) -> Self {
        SsrUnit {
            slot,
            shadow: ShadowCfg::default(),
            pending: None,
            active: None,
            data_fifo: VecDeque::new(),
            wdata_fifo: VecDeque::new(),
            last_strctl_len: 0,
            mem_reads: 0,
            mem_writes: 0,
            idx_word_fetches: 0,
            zero_injections: 0,
            busy_cycles: 0,
        }
    }

    // ---- configuration interface ------------------------------------

    /// Write a shadow config field. `Launch` commits the shadow; returns
    /// `false` if the job queue is full (the core must retry).
    pub fn cfg_write(&mut self, field: SsrField, value: i64) -> bool {
        match field {
            SsrField::DataBase => self.shadow.data_base = value as u64,
            SsrField::Bound0 => self.shadow.bounds[0] = value as u64,
            SsrField::Bound1 => self.shadow.bounds[1] = value as u64,
            SsrField::Bound2 => self.shadow.bounds[2] = value as u64,
            SsrField::Bound3 => self.shadow.bounds[3] = value as u64,
            SsrField::Stride0 => self.shadow.strides[0] = value,
            SsrField::Stride1 => self.shadow.strides[1] = value,
            SsrField::Stride2 => self.shadow.strides[2] = value,
            SsrField::Stride3 => self.shadow.strides[3] = value,
            SsrField::IdxBase => self.shadow.idx_base = value as u64,
            SsrField::IdxLen => self.shadow.idx_len = value as u64,
            SsrField::IdxSize => self.shadow.idx_size = value as u8,
            SsrField::IdxShift => self.shadow.idx_shift = value as u8,
            SsrField::Launch => {
                let job = self.shadow.job(Mode::from_launch(value));
                if self.active.is_none() {
                    self.active = Some(ActiveJob::new(job));
                } else if self.pending.is_none() {
                    self.pending = Some(job);
                } else {
                    return false;
                }
            }
            SsrField::StrCtlLen | SsrField::Done => panic!("read-only SSR field {field:?}"),
        }
        true
    }

    pub fn cfg_read(&self, field: SsrField) -> i64 {
        match field {
            SsrField::StrCtlLen => self.last_strctl_len as i64,
            SsrField::Done => i64::from(self.idle()),
            SsrField::DataBase => self.shadow.data_base as i64,
            SsrField::IdxLen => self.shadow.idx_len as i64,
            _ => 0,
        }
    }

    /// Unit is idle: no active or pending job. (Read FIFO residue may
    /// still be drained by the FPU.)
    pub fn idle(&self) -> bool {
        self.active.is_none() && self.pending.is_none()
    }

    /// Write-side fully drained (for `core_fpu_fence`).
    pub fn drained(&self) -> bool {
        self.idle() && self.wdata_fifo.is_empty()
    }

    // ---- FPU-side interface -------------------------------------------

    pub fn can_pop_data(&self) -> bool {
        !self.data_fifo.is_empty()
    }

    pub fn pop_data(&mut self) -> Option<f64> {
        self.data_fifo.pop_front()
    }

    pub fn can_push_wdata(&self) -> bool {
        self.wdata_fifo.len() < DATA_FIFO_DEPTH
    }

    pub fn push_wdata(&mut self, v: f64) -> bool {
        if !self.can_push_wdata() {
            return false;
        }
        self.wdata_fifo.push_back(v);
        true
    }

    // ---- comparator-side interface -------------------------------------

    pub fn match_mode(&self) -> Option<super::MatchMode> {
        // A job that already received its end token is only draining its
        // value datapath — it must not re-bind the comparator (otherwise
        // a fresh job on the other ISSR could be joined against a stale,
        // exhausted index stream).
        match self.active.as_ref().filter(|j| !j.end_seen).map(|j| j.cfg.mode) {
            Some(Mode::Intersect) => Some(super::MatchMode::Intersect),
            Some(Mode::Union) => Some(super::MatchMode::Union),
            Some(Mode::UnionIdx) => Some(super::MatchMode::UnionIdx),
            _ => None,
        }
    }

    pub fn idx_head(&self) -> Option<u64> {
        self.active.as_ref().and_then(|j| j.idx_fifo.front().copied())
    }

    pub fn pop_idx(&mut self) -> u64 {
        let j = self.active.as_mut().expect("no active job");
        j.idx_consumed += 1;
        j.idx_fifo.pop_front().expect("idx fifo empty")
    }

    pub fn cmd_space(&self) -> bool {
        self.active
            .as_ref()
            .map(|j| j.cmd_fifo.len() < CMD_FIFO_DEPTH)
            .unwrap_or(false)
    }

    pub fn push_cmd(&mut self, c: DataCmd) {
        self.active.as_mut().expect("no active job").cmd_fifo.push_back(c);
    }

    /// Egress: receive a joint index from the comparator.
    pub fn joint_idx_space(&self) -> bool {
        self.active
            .as_ref()
            .map(|j| j.idx_in.len() < super::JOINT_IDX_DEPTH)
            .unwrap_or(false)
    }

    pub fn push_joint_idx(&mut self, idx: u64) {
        let j = self.active.as_mut().expect("no active egress job");
        j.idx_in.push_back(idx);
        j.joint_received += 1;
    }

    pub fn signal_end(&mut self) {
        if let Some(j) = self.active.as_mut() {
            j.end_seen = true;
            j.strctl_len = match j.cfg.mode {
                Mode::Egress | Mode::EgressIdx => j.joint_received,
                _ => j.strctl_len,
            };
        }
    }

    // ---- per-cycle memory tick --------------------------------------

    /// Advance the data movers by one cycle. `port_free` tells whether
    /// this unit's memory port is available; returns `true` if the port
    /// was consumed. At most one memory access per cycle per unit (§2.2).
    pub fn tick(&mut self, tcdm: &mut Tcdm, port_free: bool) -> bool {
        let Some(job) = self.active.as_mut() else {
            return false;
        };
        self.busy_cycles += 1;
        let mut port_used = false;

        match job.cfg.mode {
            Mode::AffineRead => {
                if port_free && self.data_fifo.len() < DATA_FIFO_DEPTH {
                    if let Some(addr) = job.gen.peek() {
                        if let Access::Granted(bits) = tcdm.try_read(addr, 8) {
                            self.data_fifo.push_back(f64::from_bits(bits));
                            job.gen.advance();
                            job.elems_done += 1;
                            self.mem_reads += 1;
                        }
                        port_used = true;
                    }
                }
            }
            Mode::AffineWrite => {
                if port_free && !self.wdata_fifo.is_empty() {
                    if let Some(addr) = job.gen.peek() {
                        let v = *self.wdata_fifo.front().unwrap();
                        if let Access::Granted(_) = tcdm.try_write(addr, 8, v.to_bits()) {
                            self.wdata_fifo.pop_front();
                            job.gen.advance();
                            job.elems_done += 1;
                            self.mem_writes += 1;
                        }
                        port_used = true;
                    }
                }
            }
            Mode::IndirectRead => {
                // Data priority; fall back to index-word fetch.
                if port_free && !job.idx_fifo.is_empty() && self.data_fifo.len() < DATA_FIFO_DEPTH {
                    let idx = *job.idx_fifo.front().unwrap();
                    let addr = job.cfg.affine.base + (idx << job.cfg.idx_shift);
                    if let Access::Granted(_) = tcdm.try_read(addr, 8) {
                        self.data_fifo.push_back(tcdm.peek_f64(addr));
                        job.idx_fifo.pop_front();
                        job.idx_consumed += 1;
                        job.elems_done += 1;
                        self.mem_reads += 1;
                    }
                    port_used = true;
                } else if port_free {
                    port_used = Self::fetch_idx_word(job, tcdm, &mut self.idx_word_fetches, &mut self.mem_reads);
                }
            }
            Mode::IndirectWrite => {
                if port_free && !job.idx_fifo.is_empty() && !self.wdata_fifo.is_empty() {
                    let idx = *job.idx_fifo.front().unwrap();
                    let addr = job.cfg.affine.base + (idx << job.cfg.idx_shift);
                    let v = *self.wdata_fifo.front().unwrap();
                    if let Access::Granted(_) = tcdm.try_write(addr, 8, v.to_bits()) {
                        self.wdata_fifo.pop_front();
                        job.idx_fifo.pop_front();
                        job.idx_consumed += 1;
                        job.elems_done += 1;
                        self.mem_writes += 1;
                    }
                    port_used = true;
                } else if port_free {
                    port_used = Self::fetch_idx_word(job, tcdm, &mut self.idx_word_fetches, &mut self.mem_reads);
                }
            }
            Mode::Intersect | Mode::Union => {
                // 1) Skips are free (position bookkeeping only).
                while job.cmd_fifo.front() == Some(&DataCmd::Skip) {
                    job.cmd_fifo.pop_front();
                    job.val_pos += 1;
                }
                // 2) One zero injection per cycle, no memory access.
                if job.cmd_fifo.front() == Some(&DataCmd::Zero)
                    && self.data_fifo.len() < DATA_FIFO_DEPTH
                {
                    job.cmd_fifo.pop_front();
                    self.data_fifo.push_back(0.0);
                    self.zero_injections += 1;
                    job.elems_done += 1;
                }
                // 3) Port: keep the comparator fed — the index prefetch
                //    FIFO ("decoupling FIFO" + outstanding-request
                //    counter, §2.1.1) refills below a low-water mark with
                //    priority over value fetches; otherwise values first.
                let idx_low = job.idx_fifo.len() < 4 && !job.idx_fetch.done();
                if port_free && idx_low {
                    port_used = Self::fetch_idx_word(job, tcdm, &mut self.idx_word_fetches, &mut self.mem_reads);
                }
                if !port_used
                    && port_free
                    && job.cmd_fifo.front() == Some(&DataCmd::Fetch)
                    && self.data_fifo.len() < DATA_FIFO_DEPTH
                {
                    let addr = job.cfg.affine.base + job.val_pos * 8;
                    if let Access::Granted(_) = tcdm.try_read(addr, 8) {
                        self.data_fifo.push_back(tcdm.peek_f64(addr));
                        job.cmd_fifo.pop_front();
                        job.val_pos += 1;
                        job.elems_done += 1;
                        self.mem_reads += 1;
                    }
                    port_used = true;
                } else if !port_used && port_free {
                    port_used = Self::fetch_idx_word(job, tcdm, &mut self.idx_word_fetches, &mut self.mem_reads);
                }
            }
            Mode::UnionIdx => {
                // Structure-only: the value datapath is dark — the port
                // only ever carries index-word fetches for the comparator.
                if port_free {
                    port_used = Self::fetch_idx_word(job, tcdm, &mut self.idx_word_fetches, &mut self.mem_reads);
                }
            }
            Mode::Egress => {
                // Coalesce received joint indices into the word buffer.
                let per_word = 8 >> job.cfg.idx_size;
                while job.coalesce_n < per_word {
                    let Some(idx) = job.idx_in.pop_front() else { break };
                    let bits = 8 * (1u64 << job.cfg.idx_size);
                    let shifted = if bits == 64 { idx } else { idx & ((1 << bits) - 1) };
                    job.coalesce_buf |= shifted << (bits * job.coalesce_n);
                    job.coalesce_n += 1;
                }
                // Port: data writes take priority; a full (or final
                // partial) index word goes out when data is not ready.
                let flush_partial = job.end_seen
                    && job.coalesce_n > 0
                    && job.idx_written + job.coalesce_n >= job.joint_received;
                let idx_word_ready = job.coalesce_n == per_word || flush_partial;
                if port_free && !self.wdata_fifo.is_empty() {
                    let addr = job.cfg.affine.base + job.elems_done * 8;
                    let v = *self.wdata_fifo.front().unwrap();
                    if let Access::Granted(_) = tcdm.try_write(addr, 8, v.to_bits()) {
                        self.wdata_fifo.pop_front();
                        job.elems_done += 1;
                        self.mem_writes += 1;
                    }
                    port_used = true;
                } else if port_free && idx_word_ready {
                    let addr = job.cfg.idx_base + job.idx_words_written * 8;
                    if let Access::Granted(_) = tcdm.try_write(addr, 8, job.coalesce_buf) {
                        job.idx_words_written += 1;
                        job.idx_written += job.coalesce_n;
                        job.coalesce_buf = 0;
                        job.coalesce_n = 0;
                        self.mem_writes += 1;
                    }
                    port_used = true;
                }
            }
            Mode::EgressIdx => {
                // Structure-only egress: same coalescer as `Egress`, but
                // the value write channel never arms.
                let per_word = 8 >> job.cfg.idx_size;
                while job.coalesce_n < per_word {
                    let Some(idx) = job.idx_in.pop_front() else { break };
                    let bits = 8 * (1u64 << job.cfg.idx_size);
                    let shifted = if bits == 64 { idx } else { idx & ((1 << bits) - 1) };
                    job.coalesce_buf |= shifted << (bits * job.coalesce_n);
                    job.coalesce_n += 1;
                }
                let flush_partial = job.end_seen
                    && job.coalesce_n > 0
                    && job.idx_written + job.coalesce_n >= job.joint_received;
                if port_free && (job.coalesce_n == per_word || flush_partial) {
                    let addr = job.cfg.idx_base + job.idx_words_written * 8;
                    if let Access::Granted(_) = tcdm.try_write(addr, 8, job.coalesce_buf) {
                        job.idx_words_written += 1;
                        job.idx_written += job.coalesce_n;
                        job.coalesce_buf = 0;
                        job.coalesce_n = 0;
                        self.mem_writes += 1;
                    }
                    port_used = true;
                }
            }
        }

        // Retire finished job; promote pending shadow job.
        if self.active.as_ref().map(|j| j.finished()).unwrap_or(false) {
            let j = self.active.take().unwrap();
            self.last_strctl_len = j.strctl_len;
            if let Some(cfg) = self.pending.take() {
                self.active = Some(ActiveJob::new(cfg));
            }
        }
        port_used
    }

    fn fetch_idx_word(
        job: &mut ActiveJob,
        tcdm: &mut Tcdm,
        idx_word_fetches: &mut u64,
        mem_reads: &mut u64,
    ) -> bool {
        if job.idx_fetch.done() {
            return false;
        }
        let Some((word_addr, first_k, count)) = job.idx_fetch.next_word() else {
            return false;
        };
        if job.idx_fifo.len() + count as usize > IDX_FIFO_DEPTH {
            return false;
        }
        if let Access::Granted(word) = tcdm.try_read(word_addr, 8) {
            job.idx_fetch.serialize(word, word_addr, first_k, count, &mut job.idx_fifo);
            *idx_word_fetches += 1;
            *mem_reads += 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::ssr_mode;

    fn tcdm_with_f64(values: &[f64], base: u64) -> Tcdm {
        let mut t = Tcdm::new(64 << 10, 32);
        for (i, v) in values.iter().enumerate() {
            t.poke_f64(base + 8 * i as u64, *v);
        }
        t
    }

    fn drain(unit: &mut SsrUnit, tcdm: &mut Tcdm, n: usize, limit: u64) -> Vec<f64> {
        let mut out = vec![];
        let mut cycle = 0u64;
        while out.len() < n {
            cycle += 1;
            assert!(cycle < limit, "timeout draining unit (got {} of {n})", out.len());
            tcdm.new_cycle(cycle);
            unit.tick(tcdm, true);
            if let Some(v) = unit.pop_data() {
                out.push(v);
            }
        }
        out
    }

    fn launch(unit: &mut SsrUnit, fields: &[(SsrField, i64)], mode: i64) {
        for (f, v) in fields {
            assert!(unit.cfg_write(*f, *v));
        }
        assert!(unit.cfg_write(SsrField::Launch, mode));
    }

    #[test]
    fn affine_read_streams_values() {
        let vals = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut t = tcdm_with_f64(&vals, 0x100);
        let mut u = SsrUnit::new(0);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x100),
                (SsrField::Bound0, 5),
                (SsrField::Stride0, 8),
                (SsrField::Bound1, 1),
                (SsrField::Bound2, 1),
                (SsrField::Bound3, 1),
            ],
            ssr_mode::AFFINE_READ,
        );
        assert_eq!(drain(&mut u, &mut t, 5, 1000), vals);
        // allow retire tick
        t.new_cycle(999);
        u.tick(&mut t, true);
        assert!(u.idle());
    }

    #[test]
    fn affine_write_stores_values() {
        let mut t = Tcdm::new(64 << 10, 32);
        let mut u = SsrUnit::new(2);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x200),
                (SsrField::Bound0, 3),
                (SsrField::Stride0, 8),
                (SsrField::Bound1, 1),
                (SsrField::Bound2, 1),
                (SsrField::Bound3, 1),
            ],
            ssr_mode::AFFINE_WRITE,
        );
        for (i, v) in [7.0, 8.0, 9.0].iter().enumerate() {
            t.new_cycle(i as u64 + 1);
            assert!(u.push_wdata(*v));
            u.tick(&mut t, true);
        }
        let mut cycle = 10;
        while !u.idle() {
            cycle += 1;
            t.new_cycle(cycle);
            u.tick(&mut t, true);
            assert!(cycle < 100);
        }
        assert_eq!(t.peek_f64(0x200), 7.0);
        assert_eq!(t.peek_f64(0x208), 8.0);
        assert_eq!(t.peek_f64(0x210), 9.0);
    }

    #[test]
    fn indirect_read_gathers() {
        // b = [10,20,30,40,50,60] at 0x400; indices [5,0,3] as u16 at 0x300
        let mut t = tcdm_with_f64(&[10.0, 20.0, 30.0, 40.0, 50.0, 60.0], 0x400);
        for (i, idx) in [5u64, 0, 3].iter().enumerate() {
            t.poke(0x300 + 2 * i as u64, 2, *idx);
        }
        let mut u = SsrUnit::new(1);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x400),
                (SsrField::IdxBase, 0x300),
                (SsrField::IdxLen, 3),
                (SsrField::IdxSize, 1), // 16-bit
                (SsrField::IdxShift, 3), // *8 bytes
            ],
            ssr_mode::INDIRECT_READ,
        );
        assert_eq!(drain(&mut u, &mut t, 3, 1000), vec![60.0, 10.0, 40.0]);
    }

    #[test]
    fn indirect_read_unaligned_idx_base() {
        // index array starts at an odd halfword offset within a word
        let mut t = tcdm_with_f64(&[1.0, 2.0, 3.0, 4.0], 0x800);
        for (i, idx) in [2u64, 1, 3, 0].iter().enumerate() {
            t.poke(0x306 + 2 * i as u64, 2, *idx);
        }
        let mut u = SsrUnit::new(1);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x800),
                (SsrField::IdxBase, 0x306),
                (SsrField::IdxLen, 4),
                (SsrField::IdxSize, 1),
                (SsrField::IdxShift, 3),
            ],
            ssr_mode::INDIRECT_READ,
        );
        assert_eq!(drain(&mut u, &mut t, 4, 1000), vec![3.0, 2.0, 4.0, 1.0]);
    }

    #[test]
    fn indirect_steady_state_throughput_matches_arbitration_limit() {
        // 16-bit indices: 4 per word -> peak 4 elements per 5 cycles (80%).
        let n = 400usize;
        let vals: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut t = tcdm_with_f64(&vals, 0x8000);
        for i in 0..n {
            t.poke(0x300 + 2 * i as u64, 2, (i % n) as u64);
        }
        let mut u = SsrUnit::new(1);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x8000),
                (SsrField::IdxBase, 0x300),
                (SsrField::IdxLen, n as i64),
                (SsrField::IdxSize, 1),
                (SsrField::IdxShift, 3),
            ],
            ssr_mode::INDIRECT_READ,
        );
        let mut cycle = 0u64;
        let mut got = 0usize;
        while got < n {
            cycle += 1;
            assert!(cycle < 10_000);
            t.new_cycle(cycle);
            u.tick(&mut t, true);
            if u.pop_data().is_some() {
                got += 1;
            }
        }
        let util = n as f64 / cycle as f64;
        assert!(
            (0.74..=0.81).contains(&util),
            "16-bit indirection utilization {util} not near 0.8 ({cycle} cycles)"
        );
    }

    #[test]
    fn indirect_write_scatters() {
        let mut t = Tcdm::new(64 << 10, 32);
        for (i, idx) in [3u64, 1].iter().enumerate() {
            t.poke(0x300 + 4 * i as u64, 4, *idx);
        }
        let mut u = SsrUnit::new(0);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x600),
                (SsrField::IdxBase, 0x300),
                (SsrField::IdxLen, 2),
                (SsrField::IdxSize, 2), // 32-bit
                (SsrField::IdxShift, 3),
            ],
            ssr_mode::INDIRECT_WRITE,
        );
        let mut cycle = 0;
        let mut pushed = 0;
        while !u.idle() {
            cycle += 1;
            assert!(cycle < 100);
            t.new_cycle(cycle);
            if pushed < 2 && u.can_push_wdata() {
                u.push_wdata([42.0, 43.0][pushed]);
                pushed += 1;
            }
            u.tick(&mut t, true);
        }
        assert_eq!(t.peek_f64(0x600 + 3 * 8), 42.0);
        assert_eq!(t.peek_f64(0x600 + 8), 43.0);
    }

    #[test]
    fn pending_job_promotes_after_active() {
        let mut t = tcdm_with_f64(&[1.0, 2.0], 0x100);
        t.poke_f64(0x110, 5.0);
        let mut u = SsrUnit::new(0);
        let base_fields = [
            (SsrField::Bound0, 2),
            (SsrField::Stride0, 8),
            (SsrField::Bound1, 1),
            (SsrField::Bound2, 1),
            (SsrField::Bound3, 1),
        ];
        let mut f1 = vec![(SsrField::DataBase, 0x100i64)];
        f1.extend_from_slice(&base_fields);
        launch(&mut u, &f1, ssr_mode::AFFINE_READ);
        // queue a second job (shadow) while the first runs
        assert!(u.cfg_write(SsrField::DataBase, 0x110));
        assert!(u.cfg_write(SsrField::Bound0, 1));
        assert!(u.cfg_write(SsrField::Launch, ssr_mode::AFFINE_READ));
        // a third launch must be refused
        assert!(!u.cfg_write(SsrField::Launch, ssr_mode::AFFINE_READ));
        let out = drain(&mut u, &mut t, 3, 1000);
        assert_eq!(out, vec![1.0, 2.0, 5.0]);
    }

    #[test]
    fn egress_writes_data_and_coalesced_indices() {
        let mut t = Tcdm::new(64 << 10, 32);
        let mut u = SsrUnit::new(2);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x700),
                (SsrField::IdxBase, 0x500),
                (SsrField::IdxSize, 1), // 16-bit
            ],
            ssr_mode::EGRESS,
        );
        // comparator hands over 5 joint indices and 5 data elements
        let idxs = [2u64, 4, 7, 9, 11];
        let data = [1.5, 2.5, 3.5, 4.5, 5.5];
        let mut cycle = 0u64;
        let mut sent = 0usize;
        while !u.idle() {
            cycle += 1;
            assert!(cycle < 1000, "egress did not finish");
            t.new_cycle(cycle);
            if sent < 5 {
                if u.joint_idx_space() && u.can_push_wdata() {
                    u.push_joint_idx(idxs[sent]);
                    u.push_wdata(data[sent]);
                    sent += 1;
                    if sent == 5 {
                        u.signal_end();
                    }
                }
            }
            u.tick(&mut t, true);
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(t.peek_f64(0x700 + 8 * i as u64), *v, "data[{i}]");
        }
        for (i, idx) in idxs.iter().enumerate() {
            assert_eq!(t.peek(0x500 + 2 * i as u64, 2), *idx, "idx[{i}]");
        }
        assert_eq!(u.last_strctl_len, 5);
    }

    #[test]
    fn egress_idx_writes_indices_without_values() {
        let mut t = Tcdm::new(64 << 10, 32);
        let mut u = SsrUnit::new(2);
        launch(
            &mut u,
            &[
                (SsrField::IdxBase, 0x500),
                (SsrField::IdxSize, 1), // 16-bit
            ],
            ssr_mode::EGRESS_IDX,
        );
        let idxs = [2u64, 4, 7, 9, 11];
        let mut cycle = 0u64;
        let mut sent = 0usize;
        while !u.idle() {
            cycle += 1;
            assert!(cycle < 1000, "egress-idx did not finish");
            t.new_cycle(cycle);
            if sent < 5 && u.joint_idx_space() {
                u.push_joint_idx(idxs[sent]);
                sent += 1;
                if sent == 5 {
                    u.signal_end();
                }
            }
            u.tick(&mut t, true);
        }
        for (i, idx) in idxs.iter().enumerate() {
            assert_eq!(t.peek(0x500 + 2 * i as u64, 2), *idx, "idx[{i}]");
        }
        assert_eq!(u.last_strctl_len, 5);
        assert_eq!(u.mem_writes, 2); // 5 u16 indices = 2 coalesced words
    }

    #[test]
    fn union_idx_only_fetches_index_words() {
        // 8 u16 indices at 0x300; no value array configured at all.
        let mut t = Tcdm::new(64 << 10, 32);
        for i in 0..8u64 {
            t.poke(0x300 + 2 * i, 2, 3 * i);
        }
        let mut u = SsrUnit::new(0);
        launch(
            &mut u,
            &[
                (SsrField::IdxBase, 0x300),
                (SsrField::IdxLen, 8),
                (SsrField::IdxSize, 1),
            ],
            ssr_mode::UNION_IDX,
        );
        assert_eq!(u.match_mode(), Some(crate::sim::ssr::MatchMode::UnionIdx));
        // Stream the indices through the comparator-side interface.
        let mut got = vec![];
        let mut cycle = 0u64;
        while got.len() < 8 {
            cycle += 1;
            assert!(cycle < 1000);
            t.new_cycle(cycle);
            u.tick(&mut t, true);
            if u.idx_head().is_some() {
                got.push(u.pop_idx());
            }
        }
        assert_eq!(got, (0..8).map(|i| 3 * i).collect::<Vec<u64>>());
        assert_eq!(u.mem_reads, 2); // 8 u16 indices = 2 word fetches
        assert_eq!(u.zero_injections, 0);
        assert!(u.data_fifo.is_empty(), "structure-only mode must not touch values");
        // End-of-join retires the unit with no drain phase.
        u.signal_end();
        t.new_cycle(cycle + 1);
        u.tick(&mut t, true);
        assert!(u.idle());
    }

    #[test]
    fn match_mode_fetch_skip_zero_commands() {
        // fiber values [10, 20, 30] at 0x100; drive the cmd fifo directly.
        let mut t = tcdm_with_f64(&[10.0, 20.0, 30.0], 0x100);
        // indices (16-bit) — content irrelevant here, fetched for the cmp.
        for i in 0..3u64 {
            t.poke(0x300 + 2 * i, 2, i);
        }
        let mut u = SsrUnit::new(0);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x100),
                (SsrField::IdxBase, 0x300),
                (SsrField::IdxLen, 3),
                (SsrField::IdxSize, 1),
            ],
            ssr_mode::INTERSECT,
        );
        u.push_cmd(DataCmd::Fetch); // -> 10
        u.push_cmd(DataCmd::Skip); // skip 20
        u.push_cmd(DataCmd::Zero); // -> 0.0
        u.push_cmd(DataCmd::Fetch); // -> 30
        let out = drain(&mut u, &mut t, 3, 1000);
        assert_eq!(out, vec![10.0, 0.0, 30.0]);
        assert_eq!(u.zero_injections, 1);
    }

    #[test]
    fn port_denied_means_no_progress() {
        let mut t = tcdm_with_f64(&[1.0], 0x100);
        let mut u = SsrUnit::new(0);
        launch(
            &mut u,
            &[
                (SsrField::DataBase, 0x100),
                (SsrField::Bound0, 1),
                (SsrField::Stride0, 8),
                (SsrField::Bound1, 1),
                (SsrField::Bound2, 1),
                (SsrField::Bound3, 1),
            ],
            ssr_mode::AFFINE_READ,
        );
        t.new_cycle(1);
        assert!(!u.tick(&mut t, false)); // port withheld
        assert!(u.pop_data().is_none());
        t.new_cycle(2);
        assert!(u.tick(&mut t, true));
        assert_eq!(u.pop_data(), Some(1.0));
    }
}
