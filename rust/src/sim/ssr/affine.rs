//! The 4-level nested affine address generator all SSR variants reuse
//! (§2.1.1: "all generation modes reuse the existing affine address
//! generator with up to four nested levels").
//!
//! Level 0 is the innermost loop. `bounds[i]` are element counts,
//! `strides[i]` byte strides applied when level `i` increments.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AffineCfg {
    pub base: u64,
    pub bounds: [u64; 4],
    pub strides: [i64; 4],
}

impl AffineCfg {
    /// A flat 1D stream of `n` elements of `elem_bytes` each.
    pub fn linear(base: u64, n: u64, elem_bytes: u64) -> Self {
        AffineCfg {
            base,
            bounds: [n, 1, 1, 1],
            strides: [elem_bytes as i64, 0, 0, 0],
        }
    }

    pub fn total(&self) -> u64 {
        self.bounds.iter().product()
    }
}

/// Iterating state of the affine generator.
#[derive(Clone, Debug)]
pub struct AffineGen {
    cfg: AffineCfg,
    idx: [u64; 4],
    addr: u64,
    remaining: u64,
}

impl AffineGen {
    pub fn new(cfg: AffineCfg) -> Self {
        let remaining = cfg.total();
        AffineGen { cfg, idx: [0; 4], addr: cfg.base, remaining }
    }

    pub fn done(&self) -> bool {
        self.remaining == 0
    }

    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// The address the next `next()`/`advance()` will emit, without
    /// advancing (hot-path helper: the data movers probe every cycle but
    /// only advance on a port grant).
    #[inline]
    pub fn peek(&self) -> Option<u64> {
        if self.remaining == 0 {
            None
        } else {
            Some(self.addr)
        }
    }

    /// Advance past the current address (must not be `done()`).
    #[inline]
    pub fn advance(&mut self) {
        debug_assert!(self.remaining > 0);
        self.remaining -= 1;
        for lvl in 0..4 {
            self.idx[lvl] += 1;
            if self.idx[lvl] < self.cfg.bounds[lvl] {
                self.addr = self.addr.wrapping_add(self.cfg.strides[lvl] as u64);
                return;
            }
            self.addr = self
                .addr
                .wrapping_sub((self.cfg.strides[lvl] * (self.cfg.bounds[lvl] as i64 - 1)) as u64);
            self.idx[lvl] = 0;
        }
    }

    /// Emit the next address, advancing the nested counters.
    pub fn next(&mut self) -> Option<u64> {
        if self.remaining == 0 {
            return None;
        }
        let out = self.addr;
        self.remaining -= 1;
        // advance: carry-ripple through the 4 levels
        for lvl in 0..4 {
            self.idx[lvl] += 1;
            if self.idx[lvl] < self.cfg.bounds[lvl] {
                self.addr = self.addr.wrapping_add(self.cfg.strides[lvl] as u64);
                break;
            }
            // wrap this level: undo its contribution, carry to the next
            self.addr = self
                .addr
                .wrapping_sub((self.cfg.strides[lvl] * (self.cfg.bounds[lvl] as i64 - 1)) as u64);
            self.idx[lvl] = 0;
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_stream() {
        let mut g = AffineGen::new(AffineCfg::linear(0x100, 4, 8));
        let addrs: Vec<u64> = std::iter::from_fn(|| g.next()).collect();
        assert_eq!(addrs, vec![0x100, 0x108, 0x110, 0x118]);
        assert!(g.done());
        assert_eq!(g.next(), None);
    }

    #[test]
    fn two_level_nest() {
        // 3 elements of 8B, repeated over 2 rows 0x100 apart.
        let cfg = AffineCfg {
            base: 0,
            bounds: [3, 2, 1, 1],
            strides: [8, 0x100, 0, 0],
        };
        let mut g = AffineGen::new(cfg);
        let addrs: Vec<u64> = std::iter::from_fn(|| g.next()).collect();
        assert_eq!(addrs, vec![0, 8, 16, 0x100, 0x108, 0x110]);
    }

    #[test]
    fn negative_stride() {
        let cfg = AffineCfg {
            base: 0x40,
            bounds: [3, 1, 1, 1],
            strides: [-8, 0, 0, 0],
        };
        let mut g = AffineGen::new(cfg);
        let addrs: Vec<u64> = std::iter::from_fn(|| g.next()).collect();
        assert_eq!(addrs, vec![0x40, 0x38, 0x30]);
    }

    #[test]
    fn revisit_pattern_inner_repeat() {
        // bounds [2,3]: inner counts 2 with stride 0 (repeat each), outer
        // stride 8: emits each word twice.
        let cfg = AffineCfg {
            base: 0,
            bounds: [2, 3, 1, 1],
            strides: [0, 8, 0, 0],
        };
        let mut g = AffineGen::new(cfg);
        let addrs: Vec<u64> = std::iter::from_fn(|| g.next()).collect();
        assert_eq!(addrs, vec![0, 0, 8, 8, 16, 16]);
    }

    #[test]
    fn four_level_count() {
        let cfg = AffineCfg {
            base: 0,
            bounds: [2, 3, 4, 5],
            strides: [8, 16, 32, 64],
        };
        let mut g = AffineGen::new(cfg);
        let n = std::iter::from_fn(|| g.next()).count();
        assert_eq!(n as u64, cfg.total());
        assert_eq!(n, 2 * 3 * 4 * 5);
    }
}
