//! Sparse stream semantic registers (§2): the SSR/ISSR/ESSR units, the
//! inter-SSR index comparator, and the streamer that binds them to the
//! FPU register file.
//!
//! Module map (mirrors Fig. 1):
//! - [`affine`] — the shared 4-level affine address generator,
//! - [`unit`] — one SSR slot: data movers, index fetch/serialize path,
//!   indirection, match-mode command processing, egress coalescing,
//! - [`comparator`] — the index intersect/union unit + stream control,
//! - [`streamer`] — the register switch, config interface, and port
//!   arbitration (the CC's shared port A, §2.2).

pub mod affine;
pub mod comparator;
pub mod streamer;
pub mod unit;

pub use affine::{AffineCfg, AffineGen};
pub use comparator::Comparator;
pub use streamer::{Ports, Streamer};
pub use unit::SsrUnit;

use crate::sim::isa::ssr_mode;

/// Operating mode of a launched SSR job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    AffineRead,
    AffineWrite,
    IndirectRead,
    IndirectWrite,
    Intersect,
    Union,
    Egress,
    /// Structure-only union: the value datapath is disabled — only the
    /// index fetch/serialize path runs, and the comparator performs the
    /// merge without issuing data commands or stream-control tokens.
    UnionIdx,
    /// Structure-only egress: coalesce and write joint indices, no
    /// value writeback.
    EgressIdx,
}

impl Mode {
    pub fn from_launch(v: i64) -> Mode {
        match v {
            ssr_mode::AFFINE_READ => Mode::AffineRead,
            ssr_mode::AFFINE_WRITE => Mode::AffineWrite,
            ssr_mode::INDIRECT_READ => Mode::IndirectRead,
            ssr_mode::INDIRECT_WRITE => Mode::IndirectWrite,
            ssr_mode::INTERSECT => Mode::Intersect,
            ssr_mode::UNION => Mode::Union,
            ssr_mode::EGRESS => Mode::Egress,
            ssr_mode::UNION_IDX => Mode::UnionIdx,
            ssr_mode::EGRESS_IDX => Mode::EgressIdx,
            _ => panic!("invalid SSR launch mode {v}"),
        }
    }

    pub fn is_match(self) -> bool {
        matches!(self, Mode::Intersect | Mode::Union | Mode::UnionIdx)
    }

    pub fn reads_memory(self) -> bool {
        matches!(
            self,
            Mode::AffineRead | Mode::IndirectRead | Mode::Intersect | Mode::Union | Mode::UnionIdx
        )
    }

    /// Stable trace label for this job mode (one span name per mode on
    /// the per-lane SSR timeline).
    pub fn label(self) -> &'static str {
        match self {
            Mode::AffineRead => "affine-read",
            Mode::AffineWrite => "affine-write",
            Mode::IndirectRead => "indirect-read",
            Mode::IndirectWrite => "indirect-write",
            Mode::Intersect => "intersect",
            Mode::Union => "union",
            Mode::Egress => "egress",
            Mode::UnionIdx => "union-idx",
            Mode::EgressIdx => "egress-idx",
        }
    }
}

/// Index-matching flavor of the comparator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchMode {
    Intersect,
    Union,
    /// Structure-only union (symbolic pass): merge and count, no data
    /// commands, no stream-control tokens.
    UnionIdx,
}

/// Command from the comparator to an ISSR's value datapath (§2.1.1):
/// fetch the value at the current fiber position, skip it (advance the
/// position without a memory access), or inject a zero element into the
/// data stream (union, §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataCmd {
    Fetch,
    Skip,
    Zero,
}

/// A fully-resolved job configuration (committed shadow config).
#[derive(Clone, Copy, Debug)]
pub struct JobCfg {
    pub mode: Mode,
    /// Data-address pattern for affine modes; for indirect/match/egress
    /// modes only `.base` is used (the value array base).
    pub affine: AffineCfg,
    pub idx_base: u64,
    /// Number of indices in the fiber.
    pub idx_len: u64,
    /// log2 bytes per index (0..=3).
    pub idx_size: u8,
    /// Index left-shift for power-of-two striding.
    pub idx_shift: u8,
}

// FIFO depths (default streamer configuration, §4.3: four data FIFO
// stages; index queue depth is a parameter — we use one word of the
// largest index count plus slack).
pub const DATA_FIFO_DEPTH: usize = 4;
pub const IDX_FIFO_DEPTH: usize = 16;
pub const CMD_FIFO_DEPTH: usize = 8;
pub const STRCTL_DEPTH: usize = 8;
pub const JOINT_IDX_DEPTH: usize = 8;
