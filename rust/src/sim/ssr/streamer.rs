//! The SSSR streamer (Fig. 1c): three SSR slots, the index comparator,
//! the shared configuration interface, and the register switch mapping
//! stream data channels onto FP registers ft0/ft1/ft2.
//!
//! Port topology (§2.4): the CC combines the core, FPU and ISSR0 onto one
//! TCDM port (port A) and gives ISSR1 and the ESSR exclusive ports (B, C).
//! Port A arbitration is round-robin between the core side and ISSR0.

use crate::sim::isa::SsrField;
use crate::sim::tcdm::Tcdm;

use super::comparator::{Comparator, StrCtl};
use super::unit::SsrUnit;

/// Per-cycle port state of one core complex.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ports {
    /// Port A consumed this cycle (shared: core LSU / FPU LSU / ISSR0).
    pub a_used: bool,
    /// The core side lost port A arbitration last cycle — ISSR0 yields
    /// this cycle (round-robin fairness).
    pub core_wants_a: bool,
    /// ISSR0 won port A last cycle.
    pub issr0_had_a: bool,
}

impl Ports {
    pub fn new_cycle(&mut self) {
        self.a_used = false;
    }
}

pub struct Streamer {
    pub units: [SsrUnit; 3],
    pub cmp: Comparator,
    /// `ssr_redir` CSR: FP register accesses to ft0..ft2 are redirected
    /// to the streams.
    pub enabled: bool,
}

impl Default for Streamer {
    fn default() -> Self {
        Self::new()
    }
}

impl Streamer {
    pub fn new() -> Self {
        Streamer {
            units: [SsrUnit::new(0), SsrUnit::new(1), SsrUnit::new(2)],
            cmp: Comparator::new(),
            enabled: false,
        }
    }

    /// Is FP register `f` currently a stream register?
    #[inline]
    pub fn is_stream_reg(&self, f: u8) -> bool {
        self.enabled && f < 3
    }

    pub fn cfg_write(&mut self, ssr: u8, field: SsrField, value: i64) -> bool {
        self.units[ssr as usize].cfg_write(field, value)
    }

    pub fn cfg_read(&self, ssr: u8, field: SsrField) -> i64 {
        self.units[ssr as usize].cfg_read(field)
    }

    /// Pop a stream-control token for `frep.s`.
    pub fn strctl_pop(&mut self) -> Option<StrCtl> {
        self.cmp.strctl_pop()
    }

    /// All units idle and write paths drained (for `core_fpu_fence`).
    pub fn drained(&self) -> bool {
        self.units.iter().all(|u| u.drained())
    }

    /// Advance comparator and data movers by one cycle. Port A may be
    /// claimed by ISSR0; B and C belong to ISSR1/ESSR outright.
    pub fn tick(&mut self, tcdm: &mut Tcdm, ports: &mut Ports) {
        let [u0, u1, u2] = &mut self.units;
        // Comparator first: decisions made this cycle can be serviced by
        // the data movers in the same cycle (fall-through FIFOs).
        self.cmp.tick(u0, u1, u2);

        // ISSR0 on shared port A with round-robin fairness vs. the core.
        let yield_to_core = ports.core_wants_a && ports.issr0_had_a;
        if !ports.a_used && !yield_to_core {
            if u0.tick(tcdm, true) {
                ports.a_used = true;
                ports.issr0_had_a = true;
            }
        } else {
            // port withheld: still advance free (non-port) datapaths
            u0.tick(tcdm, false);
        }
        // ISSR1 and ESSR own their ports.
        u1.tick(tcdm, true);
        u2.tick(tcdm, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::ssr_mode;

    #[test]
    fn register_switch_only_when_enabled() {
        let mut s = Streamer::new();
        assert!(!s.is_stream_reg(0));
        s.enabled = true;
        assert!(s.is_stream_reg(0));
        assert!(s.is_stream_reg(2));
        assert!(!s.is_stream_reg(3));
    }

    #[test]
    fn issr0_yields_port_a_to_core_after_winning() {
        let mut t = Tcdm::new(64 << 10, 32);
        for i in 0..16u64 {
            t.poke_f64(0x100 + 8 * i, i as f64);
        }
        let mut s = Streamer::new();
        s.cfg_write(0, SsrField::DataBase, 0x100);
        s.cfg_write(0, SsrField::Bound0, 16);
        s.cfg_write(0, SsrField::Stride0, 8);
        s.cfg_write(0, SsrField::Bound1, 1);
        s.cfg_write(0, SsrField::Bound2, 1);
        s.cfg_write(0, SsrField::Bound3, 1);
        s.cfg_write(0, SsrField::Launch, ssr_mode::AFFINE_READ);

        let mut ports = Ports::default();
        // cycle 1: ISSR0 wins port A.
        t.new_cycle(1);
        ports.new_cycle();
        s.tick(&mut t, &mut ports);
        assert!(ports.a_used && ports.issr0_had_a);
        // core reports it wanted the port; next cycle ISSR0 must yield.
        ports.core_wants_a = true;
        t.new_cycle(2);
        ports.new_cycle();
        s.tick(&mut t, &mut ports);
        assert!(!ports.a_used, "ISSR0 should have yielded port A");
    }

    #[test]
    fn drained_reflects_unit_state() {
        let mut s = Streamer::new();
        assert!(s.drained());
        s.cfg_write(1, SsrField::DataBase, 0x100);
        s.cfg_write(1, SsrField::Bound0, 1);
        s.cfg_write(1, SsrField::Stride0, 8);
        s.cfg_write(1, SsrField::Bound1, 1);
        s.cfg_write(1, SsrField::Bound2, 1);
        s.cfg_write(1, SsrField::Bound3, 1);
        s.cfg_write(1, SsrField::Launch, ssr_mode::AFFINE_READ);
        assert!(!s.drained());
    }
}
