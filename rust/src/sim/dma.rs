//! Cluster DMA engine.
//!
//! A wide (512-bit) DMA engine moves data between DRAM and the TCDM
//! (Table 1), programmed by the data-movement core (DMCC). Our L3
//! coordinator plays the DMCC role and enqueues [`DmaJob`]s; the engine
//! processes rows of a (possibly 2D-strided) transfer, scheduling DRAM
//! bursts (which pipeline inside the channel, see [`super::dram`]) and
//! moving up to 64 B per cycle through the TCDM wide port, retrying on
//! bank conflicts.
//!
//! Up to [`MAX_OUTSTANDING`] row bursts are in flight at a time, which is
//! what makes the double-buffered matrix transfer scheme of §4.2 resilient
//! to hundreds of cycles of interconnect latency (Fig. 6b).

use std::collections::VecDeque;

use super::mem::MemPort;
use super::tcdm::Tcdm;

pub const MAX_OUTSTANDING: usize = 4;
/// Wide-port beat size (512 bit).
pub const BEAT_BYTES: u64 = 64;

/// One (possibly 2D) DMA transfer. All addresses and sizes must be
/// multiples of 8 bytes (the TCDM word size).
#[derive(Clone, Copy, Debug)]
pub struct DmaJob {
    pub dram_addr: u64,
    pub tcdm_addr: u64,
    /// Contiguous bytes per row.
    pub row_bytes: u64,
    /// Number of rows (1 for a flat copy).
    pub rows: u64,
    /// Byte stride between row starts on the DRAM side.
    pub dram_stride: u64,
    /// Byte stride between row starts on the TCDM side.
    pub tcdm_stride: u64,
    /// Direction: true = DRAM -> TCDM (read), false = TCDM -> DRAM.
    pub to_tcdm: bool,
}

impl DmaJob {
    pub fn flat(dram_addr: u64, tcdm_addr: u64, bytes: u64, to_tcdm: bool) -> Self {
        DmaJob {
            dram_addr,
            tcdm_addr,
            row_bytes: bytes,
            rows: 1,
            dram_stride: 0,
            tcdm_stride: 0,
            to_tcdm,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.row_bytes * self.rows
    }

    fn validate(&self) {
        assert!(self.row_bytes > 0 && self.rows > 0);
        assert_eq!(self.dram_addr % 8, 0);
        assert_eq!(self.tcdm_addr % 8, 0);
        assert_eq!(self.row_bytes % 8, 0);
        if self.rows > 1 {
            assert_eq!(self.dram_stride % 8, 0);
            assert_eq!(self.tcdm_stride % 8, 0);
        }
    }
}

/// An in-flight row of the active job.
struct RowXfer {
    dram_addr: u64,
    tcdm_addr: u64,
    bytes: u64,
    /// Read path: cycle the first beat arrives; write path: unused.
    first_beat: u64,
    /// Bytes already moved through the TCDM port.
    moved: u64,
    /// Write path: all TCDM reads done, burst scheduled, completes at...
    drain_done: Option<u64>,
}

pub struct Dma {
    queue: VecDeque<DmaJob>,
    active: Option<DmaJob>,
    /// Next row index of the active job to launch.
    next_row: u64,
    inflight: VecDeque<RowXfer>,
    /// Completion counter: one increment per finished job. The coordinator
    /// uses it to sequence double-buffer phases.
    pub jobs_done: u64,
    pub jobs_submitted: u64,
    /// Busy-cycle statistic (any in-flight work).
    pub busy_cycles: u64,
    /// Main-memory bytes fetched by this engine (mirrors the backing
    /// channel's read counter, but stays per-cluster when the channel
    /// is shared by a multi-cluster system).
    pub bytes_read: u64,
    /// Main-memory bytes written back by this engine.
    pub bytes_written: u64,
}

impl Default for Dma {
    fn default() -> Self {
        Self::new()
    }
}

impl Dma {
    pub fn new() -> Self {
        Dma {
            queue: VecDeque::new(),
            active: None,
            next_row: 0,
            inflight: VecDeque::new(),
            jobs_done: 0,
            jobs_submitted: 0,
            busy_cycles: 0,
            bytes_read: 0,
            bytes_written: 0,
        }
    }

    pub fn submit(&mut self, job: DmaJob) {
        job.validate();
        self.jobs_submitted += 1;
        self.queue.push_back(job);
    }

    pub fn busy(&self) -> bool {
        self.active.is_some() || !self.queue.is_empty()
    }

    /// Tick one cycle. Moves at most one 64 B beat through the TCDM wide
    /// port (the engine has a single wide port). `mem` is this cluster's
    /// port into backing main memory — a private [`super::dram::Dram`]
    /// in the standalone topology, or a shared-HBM channel port in a
    /// multi-cluster [`super::system::System`]. Generic over the port
    /// type so the hot per-beat calls devirtualize for concrete callers
    /// (`&mut dyn MemPort` still works: `M = dyn MemPort`).
    pub fn tick<M: MemPort + ?Sized>(&mut self, now: u64, tcdm: &mut Tcdm, mem: &mut M) {
        if self.active.is_none() {
            if let Some(job) = self.queue.pop_front() {
                self.active = Some(job);
                self.next_row = 0;
            } else {
                return;
            }
        }
        self.busy_cycles += 1;
        let job = *self.active.as_ref().unwrap();

        // Launch row bursts up to the outstanding limit.
        while self.next_row < job.rows && self.inflight.len() < MAX_OUTSTANDING {
            let r = self.next_row;
            let dram_addr = job.dram_addr + r * job.dram_stride;
            let tcdm_addr = job.tcdm_addr + r * job.tcdm_stride;
            if job.to_tcdm {
                let t = mem.schedule_read(now, job.row_bytes);
                self.bytes_read += job.row_bytes;
                self.inflight.push_back(RowXfer {
                    dram_addr,
                    tcdm_addr,
                    bytes: job.row_bytes,
                    first_beat: t.first_beat,
                    moved: 0,
                    drain_done: None,
                });
            } else {
                self.inflight.push_back(RowXfer {
                    dram_addr,
                    tcdm_addr,
                    bytes: job.row_bytes,
                    first_beat: 0,
                    moved: 0,
                    drain_done: None,
                });
            }
            self.next_row += 1;
        }

        // Service the head row (in-order completion keeps TCDM writes
        // deterministic).
        if let Some(row) = self.inflight.front_mut() {
            if job.to_tcdm {
                // How many bytes have arrived from DRAM by `now`?
                let arrived = if now < row.first_beat {
                    0
                } else {
                    (((now - row.first_beat + 1) as f64) * mem.bytes_per_cycle()) as u64
                }
                .min(row.bytes);
                let pending = arrived.saturating_sub(row.moved);
                if pending >= 8 || (pending > 0 && row.moved + pending == row.bytes) {
                    let chunk = pending.min(BEAT_BYTES) & !7;
                    let chunk = if chunk == 0 { pending } else { chunk };
                    let src = row.dram_addr + row.moved;
                    let dst = row.tcdm_addr + row.moved;
                    if tcdm.try_write_wide(dst, mem.read_bytes(src, chunk as usize)) {
                        row.moved += chunk;
                    }
                }
                if row.moved == row.bytes {
                    self.inflight.pop_front();
                }
            } else {
                // TCDM -> DRAM: stream reads through the wide port, then
                // schedule the DRAM write burst once the row is drained.
                if row.moved < row.bytes {
                    let chunk = (row.bytes - row.moved).min(BEAT_BYTES);
                    let src = row.tcdm_addr + row.moved;
                    let mut buf = [0u8; BEAT_BYTES as usize];
                    let beat = &mut buf[..chunk as usize];
                    if tcdm.try_read_wide(src, beat) {
                        mem.write_bytes(row.dram_addr + row.moved, beat);
                        row.moved += chunk;
                        if row.moved == row.bytes {
                            let t = mem.schedule_write(now, row.bytes);
                            self.bytes_written += row.bytes;
                            row.drain_done = Some(t.last_beat);
                        }
                    }
                } else if let Some(done) = row.drain_done {
                    if now >= done {
                        self.inflight.pop_front();
                    }
                }
            }
        }

        // Job complete?
        if self.next_row == job.rows && self.inflight.is_empty() {
            self.active = None;
            self.jobs_done += 1;
        }
    }

    /// Quiescence probe for the cluster idle fast-forward: the earliest
    /// future cycle at which this engine can do anything, assuming no
    /// tick runs in between. `None` means it may act on the very next
    /// tick (or we cannot cheaply prove otherwise — always safe);
    /// `Some(u64::MAX)` means it is idle until someone submits a job.
    ///
    /// The analysis mirrors [`Self::tick`] exactly: with no launchable
    /// row and an in-flight head waiting on a future `first_beat` (read)
    /// or `drain_done` (write), a tick's only side effect is the
    /// busy-cycle statistic — which [`Self::fast_forward`] compensates.
    pub(crate) fn quiet_until(&self, now: u64) -> Option<u64> {
        let Some(job) = self.active.as_ref() else {
            return if self.queue.is_empty() { Some(u64::MAX) } else { None };
        };
        if self.next_row < job.rows && self.inflight.len() < MAX_OUTSTANDING {
            return None; // next tick launches another row burst
        }
        let Some(row) = self.inflight.front() else {
            return None; // job completion is imminent
        };
        if job.to_tcdm {
            // The head row cannot pop (and thus nothing else can change)
            // before its first beat arrives from the channel.
            if now + 1 < row.first_beat {
                Some(row.first_beat)
            } else {
                None
            }
        } else if row.moved < row.bytes {
            None // draining TCDM reads: may progress every cycle
        } else {
            match row.drain_done {
                Some(done) if now + 1 < done => Some(done),
                _ => None,
            }
        }
    }

    /// Apply the per-cycle side effects of `skipped` quiet ticks in one
    /// step: a quiet tick with an active job counts as busy.
    pub(crate) fn fast_forward(&mut self, skipped: u64) {
        if self.active.is_some() {
            self.busy_cycles += skipped;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::dram::Dram;
    use super::*;

    fn run_until_done(dma: &mut Dma, tcdm: &mut Tcdm, dram: &mut Dram, limit: u64) -> u64 {
        let mut now = 0;
        while dma.busy() {
            now += 1;
            assert!(now < limit, "DMA did not finish within {limit} cycles");
            tcdm.new_cycle(now);
            dma.tick(now, tcdm, dram);
        }
        now
    }

    #[test]
    fn flat_read_copies_and_takes_latency() {
        let mut tcdm = Tcdm::new(128 << 10, 32);
        let mut dram = Dram::new(1 << 16);
        let payload: Vec<u8> = (0..4096u32).map(|x| x as u8).collect();
        dram.write_bytes(0x100, &payload);
        let mut dma = Dma::new();
        dma.submit(DmaJob::flat(0x100, 0x40, 4096, true));
        let cycles = run_until_done(&mut dma, &mut tcdm, &mut dram, 100_000);
        assert_eq!(tcdm.read_bytes(0x40, 4096), &payload[..]);
        // must at least pay interconnect + dram latency + transfer
        assert!(cycles >= 16 + 88 + 4096 / 64, "cycles={cycles}");
        // and not be wildly slower (beat rate bound)
        assert!(cycles < 16 + 88 + 16 + 2 * (4096 / 57) + 64, "cycles={cycles}");
    }

    #[test]
    fn flat_write_roundtrip() {
        let mut tcdm = Tcdm::new(128 << 10, 32);
        let mut dram = Dram::new(1 << 16);
        let payload: Vec<u8> = (0..1024u32).map(|x| (x * 7) as u8).collect();
        tcdm.load_bytes(0x200, &payload);
        let mut dma = Dma::new();
        dma.submit(DmaJob::flat(0x800, 0x200, 1024, false));
        run_until_done(&mut dma, &mut tcdm, &mut dram, 100_000);
        assert_eq!(dram.read_bytes(0x800, 1024), &payload[..]);
    }

    #[test]
    fn strided_2d_transfer() {
        let mut tcdm = Tcdm::new(128 << 10, 32);
        let mut dram = Dram::new(1 << 16);
        // 4 rows of 64 B at stride 256 in DRAM, packed in TCDM.
        for r in 0..4u64 {
            let row: Vec<u8> = (0..64).map(|i| (r * 100 + i) as u8).collect();
            dram.write_bytes(r * 256, &row);
        }
        let mut dma = Dma::new();
        dma.submit(DmaJob {
            dram_addr: 0,
            tcdm_addr: 0,
            row_bytes: 64,
            rows: 4,
            dram_stride: 256,
            tcdm_stride: 64,
            to_tcdm: true,
        });
        run_until_done(&mut dma, &mut tcdm, &mut dram, 100_000);
        for r in 0..4u64 {
            let expect: Vec<u8> = (0..64).map(|i| (r * 100 + i) as u8).collect();
            assert_eq!(tcdm.read_bytes(r * 64, 64), &expect[..]);
        }
    }

    #[test]
    fn jobs_queue_fifo() {
        let mut tcdm = Tcdm::new(128 << 10, 32);
        let mut dram = Dram::new(1 << 16);
        dram.write_bytes(0, &[1u8; 64]);
        dram.write_bytes(64, &[2u8; 64]);
        let mut dma = Dma::new();
        dma.submit(DmaJob::flat(0, 0, 64, true));
        dma.submit(DmaJob::flat(64, 0, 64, true)); // overwrites
        run_until_done(&mut dma, &mut tcdm, &mut dram, 100_000);
        assert_eq!(dma.jobs_done, 2);
        assert_eq!(tcdm.read_bytes(0, 64), &[2u8; 64]);
    }

    #[test]
    fn throughput_tracks_bandwidth_throttle() {
        // 32 KiB at full vs 1/9 bandwidth: the transfer time should scale.
        let run = |gbps: f64| -> u64 {
            let mut tcdm = Tcdm::new(128 << 10, 32);
            let mut dram = Dram::with_params(1 << 20, gbps, 88, 16);
            let mut dma = Dma::new();
            dma.submit(DmaJob::flat(0, 0, 32 << 10, true));
            run_until_done(&mut dma, &mut tcdm, &mut dram, 10_000_000)
        };
        let fast = run(3.6);
        let slow = run(0.4);
        assert!(
            (slow as f64) > 6.0 * fast as f64,
            "slow={slow} fast={fast}: expected ~9x stretch"
        );
    }
}
