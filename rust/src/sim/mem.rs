//! Backing-memory interface of the simulator.
//!
//! [`MemPort`] is the contract the cluster DMA engine programs against:
//! burst timing (FCFS data-bus scheduling with interconnect and device
//! latency) plus zero-time backing-store access for DMA payload movement
//! and host-side workload setup. Two implementations exist:
//!
//! - [`super::dram::Dram`] — the original single-cluster topology: one
//!   private HBM2E channel per cluster (the paper's §4.2 configuration),
//! - [`super::system::HbmPort`] — one cluster's view of the shared
//!   multi-channel HBM of the system layer, where bursts from several
//!   clusters arbitrate for the same channel data bus.
//!
//! The burst-timing math itself lives here ([`schedule_burst`]) so both
//! topologies are cycle-identical when unloaded — which is what lets a
//! one-cluster [`super::system::System`] reproduce the standalone
//! [`super::cluster::Cluster`] exactly.

/// Timing descriptor for one scheduled burst.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BurstTiming {
    /// Cycle at which the first beat arrives back at the cluster.
    pub first_beat: u64,
    /// Cycle at which the last beat has arrived (transfer complete).
    pub last_beat: u64,
}

/// FCFS data-bus burst scheduling shared by [`super::dram::Dram`] and
/// the HBM channels of the system layer: the request travels
/// `ic_latency` cycles to the device, waits the `latency` round-trip,
/// then occupies the data bus behind any earlier burst (`busy_until`).
/// Returns the burst timing plus the cycles the burst spent queued
/// behind other traffic (0 on an idle channel) — the per-channel
/// arbitration/contention signal of the system layer.
pub(crate) fn schedule_burst(
    busy_until: &mut u64,
    now: u64,
    bytes: u64,
    bytes_per_cycle: f64,
    latency: u64,
    ic_latency: u64,
) -> (BurstTiming, u64) {
    let request_at_device = now + ic_latency;
    let unloaded_start = request_at_device + latency;
    let data_start = unloaded_start.max(*busy_until);
    let occupancy = (bytes as f64 / bytes_per_cycle).ceil() as u64;
    let data_end = data_start + occupancy.max(1);
    *busy_until = data_end;
    let timing = BurstTiming {
        first_beat: data_start + ic_latency,
        last_beat: data_end + ic_latency,
    };
    (timing, data_start - unloaded_start)
}

/// Little-endian word read out of a backing store.
pub(crate) fn peek_le(mem: &[u8], addr: u64, bytes: u64) -> u64 {
    let a = addr as usize;
    let mut v = 0u64;
    for (i, &b) in mem[a..a + bytes as usize].iter().enumerate() {
        v |= (b as u64) << (8 * i);
    }
    v
}

/// Little-endian word write into a backing store.
pub(crate) fn poke_le(mem: &mut [u8], addr: u64, bytes: u64, value: u64) {
    let a = addr as usize;
    for (i, b) in mem[a..a + bytes as usize].iter_mut().enumerate() {
        *b = (value >> (8 * i)) as u8;
    }
}

/// One cluster's port into backing main memory: burst timing for the
/// DMA engine plus zero-time payload/setup access. See the module docs
/// for the two implementations.
pub trait MemPort {
    /// Schedule a read burst of `bytes` issued at cycle `now`; returns
    /// when its beats arrive at the cluster.
    fn schedule_read(&mut self, now: u64, bytes: u64) -> BurstTiming;

    /// Schedule a write burst (timing symmetric to reads; posted writes
    /// complete when the channel has absorbed the last beat).
    fn schedule_write(&mut self, now: u64, bytes: u64) -> BurstTiming;

    /// Peak deliverable bandwidth of this port's channel in bytes per
    /// cluster cycle (the DMA uses it to pace beat arrival).
    fn bytes_per_cycle(&self) -> f64;

    /// Backing-store capacity visible through this port, in bytes.
    fn size(&self) -> usize;

    /// Zero-time backing-store read (DMA payload + result readback).
    fn read_bytes(&self, addr: u64, len: usize) -> &[u8];

    /// Zero-time backing-store write (DMA payload + host setup).
    fn write_bytes(&mut self, addr: u64, bytes: &[u8]);

    /// Read up to 8 little-endian bytes as one value.
    fn peek(&self, addr: u64, bytes: u64) -> u64 {
        peek_le(self.read_bytes(addr, bytes as usize), 0, bytes)
    }

    /// Write up to 8 little-endian bytes of one value.
    fn poke(&mut self, addr: u64, bytes: u64, value: u64) {
        let mut buf = [0u8; 8];
        poke_le(&mut buf, 0, bytes, value);
        self.write_bytes(addr, &buf[..bytes as usize]);
    }

    fn poke_f64(&mut self, addr: u64, v: f64) {
        self.poke(addr, 8, v.to_bits());
    }

    fn peek_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.peek(addr, 8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_burst_idle_channel_pays_full_latency() {
        let mut busy = 0u64;
        let (t, queued) = schedule_burst(&mut busy, 0, 576, 57.6, 88, 16);
        assert_eq!(t.first_beat, 16 + 88 + 16);
        assert_eq!(t.last_beat, 16 + 88 + 10 + 16);
        assert_eq!(queued, 0);
        assert_eq!(busy, 16 + 88 + 10);
    }

    #[test]
    fn schedule_burst_queues_behind_prior_traffic() {
        let mut busy = 0u64;
        let (a, _) = schedule_burst(&mut busy, 0, 5760, 57.6, 88, 16);
        let (b, queued) = schedule_burst(&mut busy, 0, 5760, 57.6, 88, 16);
        // second burst's data starts right after the first's occupancy
        assert_eq!(b.first_beat - 16, a.last_beat - 16);
        assert_eq!(queued, 100);
    }

    #[test]
    fn le_word_roundtrip() {
        let mut mem = vec![0u8; 32];
        poke_le(&mut mem, 3, 4, 0xA1B2_C3D4);
        assert_eq!(peek_le(&mem, 3, 4), 0xA1B2_C3D4);
        assert_eq!(mem[3], 0xD4);
        assert_eq!(mem[6], 0xA1);
    }
}
