//! Banked tightly-coupled data memory (TCDM) with per-cycle bank
//! arbitration.
//!
//! The evaluated cluster (Table 1) has `k = 32` banks of 64-bit words and
//! a 128 KiB capacity. Each bank serves at most one request per cycle;
//! requesters that lose arbitration retry the next cycle. Bank conflicts —
//! aggravated by the pseudorandom access patterns of indirection, §4.2 —
//! are the first-order effect limiting ISSR throughput in the cluster, so
//! they are modeled exactly: conflict iff two requests map to the same
//! bank in the same cycle.
//!
//! The DMA engine uses a wide 512-bit port that claims up to eight
//! consecutive banks in one cycle (Table 1: `w = 512`, `n = 64`).

/// Result of an access attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    /// Granted; loaded value (zero-extended) for reads, 0 for writes.
    Granted(u64),
    /// Bank busy this cycle — retry next cycle.
    Conflict,
}

pub struct Tcdm {
    data: Vec<u8>,
    n_banks: usize,
    /// Cycle stamp of the last grant per bank (avoids a per-cycle clear).
    bank_used_at: Vec<u64>,
    cycle: u64,
    // ---- statistics ----
    pub grants: u64,
    pub conflicts: u64,
}

impl Tcdm {
    pub fn new(size_bytes: usize, n_banks: usize) -> Self {
        assert!(n_banks.is_power_of_two(), "bank count must be a power of two");
        assert_eq!(size_bytes % 8, 0);
        Tcdm {
            data: vec![0; size_bytes],
            n_banks,
            bank_used_at: vec![u64::MAX; n_banks],
            cycle: 0,
            grants: 0,
            conflicts: 0,
        }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    pub fn n_banks(&self) -> usize {
        self.n_banks
    }

    /// Advance to a new cycle: all banks become free again.
    pub fn new_cycle(&mut self, cycle: u64) {
        self.cycle = cycle;
    }

    #[inline]
    fn bank_of(&self, addr: u64) -> usize {
        ((addr >> 3) as usize) & (self.n_banks - 1)
    }

    #[inline]
    fn bank_free(&self, bank: usize) -> bool {
        self.bank_used_at[bank] != self.cycle
    }

    #[inline]
    fn claim(&mut self, bank: usize) {
        self.bank_used_at[bank] = self.cycle;
        self.grants += 1;
    }

    /// Narrow (≤ 8 B, naturally aligned) read through a core/SSR port.
    pub fn try_read(&mut self, addr: u64, bytes: u64) -> Access {
        debug_assert!(bytes.is_power_of_two() && bytes <= 8);
        debug_assert_eq!(addr % bytes, 0, "unaligned TCDM read @ {addr:#x} x{bytes}");
        let bank = self.bank_of(addr);
        if !self.bank_free(bank) {
            self.conflicts += 1;
            return Access::Conflict;
        }
        self.claim(bank);
        Access::Granted(self.peek(addr, bytes))
    }

    /// Narrow (≤ 8 B, naturally aligned) write through a core/SSR port.
    pub fn try_write(&mut self, addr: u64, bytes: u64, value: u64) -> Access {
        debug_assert!(bytes.is_power_of_two() && bytes <= 8);
        debug_assert_eq!(addr % bytes, 0, "unaligned TCDM write @ {addr:#x} x{bytes}");
        let bank = self.bank_of(addr);
        if !self.bank_free(bank) {
            self.conflicts += 1;
            return Access::Conflict;
        }
        self.claim(bank);
        self.poke(addr, bytes, value);
        Access::Granted(0)
    }

    /// Wide DMA read of up to 64 B starting at an 8 B-aligned address.
    /// Claims every touched bank; all-or-nothing grant.
    pub fn try_read_wide(&mut self, addr: u64, out: &mut [u8]) -> bool {
        if !self.claim_wide(addr, out.len() as u64) {
            return false;
        }
        let a = addr as usize;
        out.copy_from_slice(&self.data[a..a + out.len()]);
        true
    }

    /// Wide DMA write of up to 64 B starting at an 8 B-aligned address.
    pub fn try_write_wide(&mut self, addr: u64, src: &[u8]) -> bool {
        if !self.claim_wide(addr, src.len() as u64) {
            return false;
        }
        let a = addr as usize;
        self.data[a..a + src.len()].copy_from_slice(src);
        true
    }

    fn claim_wide(&mut self, addr: u64, len: u64) -> bool {
        debug_assert!(len <= 64 && len > 0);
        debug_assert_eq!(addr % 8, 0, "DMA beats must be word-aligned");
        debug_assert_eq!(len % 8, 0, "DMA beats must be whole words");
        let first = self.bank_of(addr);
        let n = (len / 8) as usize;
        debug_assert!(n <= self.n_banks);
        for i in 0..n {
            let b = (first + i) & (self.n_banks - 1);
            if !self.bank_free(b) {
                self.conflicts += 1;
                return false;
            }
        }
        for i in 0..n {
            let b = (first + i) & (self.n_banks - 1);
            self.claim(b);
        }
        true
    }

    // ---- zero-time backdoor (test setup / result readout, no timing) ----

    pub fn peek(&self, addr: u64, bytes: u64) -> u64 {
        let a = addr as usize;
        let mut v: u64 = 0;
        for i in 0..bytes as usize {
            v |= (self.data[a + i] as u64) << (8 * i);
        }
        v
    }

    pub fn poke(&mut self, addr: u64, bytes: u64, value: u64) {
        let a = addr as usize;
        for i in 0..bytes as usize {
            self.data[a + i] = (value >> (8 * i)) as u8;
        }
    }

    pub fn peek_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.peek(addr, 8))
    }

    pub fn poke_f64(&mut self, addr: u64, v: f64) {
        self.poke(addr, 8, v.to_bits());
    }

    pub fn load_bytes(&mut self, addr: u64, bytes: &[u8]) {
        let a = addr as usize;
        self.data[a..a + bytes.len()].copy_from_slice(bytes);
    }

    pub fn read_bytes(&self, addr: u64, len: usize) -> &[u8] {
        &self.data[addr as usize..addr as usize + len]
    }

    pub fn bytes_mut(&mut self, addr: u64, len: usize) -> &mut [u8] {
        &mut self.data[addr as usize..addr as usize + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_bank_conflicts_in_one_cycle() {
        let mut t = Tcdm::new(1 << 12, 4);
        t.new_cycle(1);
        // words 0 and 4 map to bank 0 (stride n_banks words).
        assert!(matches!(t.try_read(0, 8), Access::Granted(_)));
        assert_eq!(t.try_read(4 * 8, 8), Access::Conflict);
        // different bank is fine.
        assert!(matches!(t.try_read(8, 8), Access::Granted(_)));
        // next cycle the bank frees up.
        t.new_cycle(2);
        assert!(matches!(t.try_read(4 * 8, 8), Access::Granted(_)));
    }

    #[test]
    fn subword_accesses_share_bank() {
        let mut t = Tcdm::new(1 << 12, 4);
        t.new_cycle(1);
        assert!(matches!(t.try_read(0, 2), Access::Granted(_)));
        // Same word, different halfword — still one bank, so conflict.
        assert_eq!(t.try_read(2, 2), Access::Conflict);
    }

    #[test]
    fn rw_roundtrip_all_widths() {
        let mut t = Tcdm::new(1 << 12, 8);
        let mut cycle = 0;
        for (bytes, val) in [(1u64, 0xAB), (2, 0xBEEF), (4, 0xDEADBEEF), (8, 0x0123456789ABCDEF)] {
            cycle += 1;
            t.new_cycle(cycle);
            assert!(matches!(t.try_write(64, bytes, val), Access::Granted(_)));
            cycle += 1;
            t.new_cycle(cycle);
            match t.try_read(64, bytes) {
                Access::Granted(v) => assert_eq!(v, val),
                _ => panic!("conflict"),
            }
        }
    }

    #[test]
    fn wide_claims_all_banks() {
        let mut t = Tcdm::new(1 << 12, 8);
        t.new_cycle(1);
        let mut buf = [0u8; 64];
        assert!(t.try_read_wide(0, &mut buf));
        // every bank is now busy.
        for b in 0..8 {
            assert_eq!(t.try_read(b * 8, 8), Access::Conflict);
        }
    }

    #[test]
    fn wide_all_or_nothing() {
        let mut t = Tcdm::new(1 << 12, 8);
        t.new_cycle(1);
        // claim bank 3 narrowly
        assert!(matches!(t.try_read(3 * 8, 8), Access::Granted(_)));
        let mut buf = [0u8; 64];
        // wide access overlapping bank 3 must fully fail...
        assert!(!t.try_read_wide(0, &mut buf));
        // ...without having claimed the other banks.
        assert!(matches!(t.try_read(0, 8), Access::Granted(_)));
    }

    #[test]
    fn wide_write_readback() {
        let mut t = Tcdm::new(1 << 12, 8);
        t.new_cycle(1);
        let src: Vec<u8> = (0..64).collect();
        assert!(t.try_write_wide(128, &src));
        assert_eq!(t.read_bytes(128, 64), &src[..]);
    }

    #[test]
    fn backdoor_f64() {
        let mut t = Tcdm::new(1 << 12, 8);
        t.poke_f64(40, 3.25);
        assert_eq!(t.peek_f64(40), 3.25);
    }

    #[test]
    fn conflict_stats_count() {
        let mut t = Tcdm::new(1 << 12, 4);
        t.new_cycle(1);
        let _ = t.try_read(0, 8);
        let _ = t.try_read(32, 8); // same bank
        assert_eq!(t.grants, 1);
        assert_eq!(t.conflicts, 1);
    }
}
