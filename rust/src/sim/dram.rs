//! Main-memory model: one channel of an HBM2E device plus the on-chip
//! interconnect in front of it.
//!
//! The paper connects the cluster to one of eight channels of a Micron
//! HBM2E part via DRAMSys: 3.6 Gb/s/pin (57.6 GB/s peak over a 128-bit
//! channel), 88 ns average round-trip latency, plus 16 cycles of modeled
//! one-way on-chip interconnect latency (§4.2). We reproduce those
//! first-order characteristics — peak bandwidth, fixed service latency,
//! FCFS data-bus occupancy — which are exactly the knobs Fig. 6 sweeps.
//!
//! Backing storage doubles as the simulated main memory contents.
//!
//! `Dram` is the standalone (one cluster, one private channel) topology;
//! it implements the extracted [`MemPort`] interface, whose multi-cluster
//! counterpart is the shared HBM of [`super::system`]. Both build on the
//! same [`schedule_burst`] math, so an unloaded channel times bursts
//! identically in either topology.

use super::mem::{peek_le, poke_le, schedule_burst, MemPort};

pub use super::mem::BurstTiming;

pub struct Dram {
    mem: Vec<u8>,
    /// Peak channel bandwidth in bytes per cluster cycle.
    bytes_per_cycle: f64,
    /// Average DRAM round-trip latency in cycles (PHY + controller + device).
    pub latency: u64,
    /// One-way on-chip interconnect latency in cycles (§4.2.1 sweeps this).
    pub ic_latency: u64,
    /// Data-bus occupancy horizon: the channel is busy until this cycle.
    busy_until: u64,
    // ---- statistics ----
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub bursts: u64,
}

/// 3.6 Gb/s/pin on a 128-pin channel at a 1 GHz cluster clock
/// = 57.6 GB/s = 57.6 B/cycle.
pub const GBPS_PIN_FULL: f64 = 3.6;
pub const CHANNEL_PINS: f64 = 128.0;
pub const DEFAULT_LATENCY: u64 = 88;
pub const DEFAULT_IC_LATENCY: u64 = 16;

impl Dram {
    pub fn new(size_bytes: usize) -> Self {
        Self::with_params(size_bytes, GBPS_PIN_FULL, DEFAULT_LATENCY, DEFAULT_IC_LATENCY)
    }

    pub fn with_params(size_bytes: usize, gbps_per_pin: f64, latency: u64, ic_latency: u64) -> Self {
        Dram {
            mem: vec![0; size_bytes],
            bytes_per_cycle: gbps_per_pin * CHANNEL_PINS / 8.0,
            latency,
            ic_latency,
            busy_until: 0,
            bytes_read: 0,
            bytes_written: 0,
            bursts: 0,
        }
    }

    /// Set the available channel bandwidth in Gb/s/pin (Fig. 6a sweep:
    /// simulates sharing the channel with other bus agents).
    pub fn set_gbps_per_pin(&mut self, gbps: f64) {
        self.bytes_per_cycle = gbps * CHANNEL_PINS / 8.0;
    }

    pub fn gbps_per_pin(&self) -> f64 {
        self.bytes_per_cycle * 8.0 / CHANNEL_PINS
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes_per_cycle
    }

    /// Schedule a read burst of `bytes` issued by the DMA at cycle `now`.
    /// Returns when its beats arrive at the cluster. FCFS: the data bus
    /// serves one burst at a time; requests pipeline behind each other, so
    /// only the first burst of a back-to-back train pays the full latency.
    pub fn schedule_read(&mut self, now: u64, bytes: u64) -> BurstTiming {
        self.bytes_read += bytes;
        self.schedule(now, bytes)
    }

    /// Schedule a write burst (timing symmetric to reads at this level;
    /// posted writes complete when the last beat leaves the cluster and
    /// the channel has absorbed them).
    pub fn schedule_write(&mut self, now: u64, bytes: u64) -> BurstTiming {
        self.bytes_written += bytes;
        self.schedule(now, bytes)
    }

    fn schedule(&mut self, now: u64, bytes: u64) -> BurstTiming {
        self.bursts += 1;
        let (timing, _queued) = schedule_burst(
            &mut self.busy_until,
            now,
            bytes,
            self.bytes_per_cycle,
            self.latency,
            self.ic_latency,
        );
        timing
    }

    /// Cycle until which the channel data bus is occupied.
    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }

    // ---- zero-time backing-store access (DMA payload + host setup) ----

    pub fn size(&self) -> usize {
        self.mem.len()
    }

    pub fn read_bytes(&self, addr: u64, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }

    pub fn write_bytes(&mut self, addr: u64, bytes: &[u8]) {
        self.mem[addr as usize..addr as usize + bytes.len()].copy_from_slice(bytes);
    }

    pub fn peek(&self, addr: u64, bytes: u64) -> u64 {
        peek_le(&self.mem, addr, bytes)
    }

    pub fn poke(&mut self, addr: u64, bytes: u64, value: u64) {
        poke_le(&mut self.mem, addr, bytes, value)
    }

    pub fn poke_f64(&mut self, addr: u64, v: f64) {
        self.poke(addr, 8, v.to_bits());
    }

    pub fn peek_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.peek(addr, 8))
    }
}

impl MemPort for Dram {
    fn schedule_read(&mut self, now: u64, bytes: u64) -> BurstTiming {
        Dram::schedule_read(self, now, bytes)
    }

    fn schedule_write(&mut self, now: u64, bytes: u64) -> BurstTiming {
        Dram::schedule_write(self, now, bytes)
    }

    fn bytes_per_cycle(&self) -> f64 {
        Dram::bytes_per_cycle(self)
    }

    fn size(&self) -> usize {
        Dram::size(self)
    }

    fn read_bytes(&self, addr: u64, len: usize) -> &[u8] {
        Dram::read_bytes(self, addr, len)
    }

    fn write_bytes(&mut self, addr: u64, bytes: &[u8]) {
        Dram::write_bytes(self, addr, bytes)
    }

    // peek/poke use the MemPort defaults over read_bytes/write_bytes,
    // which match the inherent accessors bit for bit.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_burst_pays_full_latency() {
        let mut d = Dram::new(1 << 16);
        let t = d.schedule_read(0, 576);
        // request travels 16, waits 88, streams 576/57.6 = 10 cycles, +16 back
        assert_eq!(t.first_beat, 16 + 88 + 16);
        assert_eq!(t.last_beat, 16 + 88 + 10 + 16);
    }

    #[test]
    fn back_to_back_bursts_pipeline() {
        let mut d = Dram::new(1 << 16);
        let a = d.schedule_read(0, 5760); // 100 cycles occupancy
        let b = d.schedule_read(1, 5760);
        // second burst's data starts right after the first's occupancy ends
        assert_eq!(b.first_beat, a.last_beat - 16 + 16); // contiguous streaming
        assert_eq!(b.last_beat - a.last_beat, 100);
    }

    #[test]
    fn throttled_bandwidth_stretches_occupancy() {
        let mut full = Dram::new(1 << 16);
        let mut tenth = Dram::new(1 << 16);
        tenth.set_gbps_per_pin(0.36);
        let a = full.schedule_read(0, 57_600);
        let b = tenth.schedule_read(0, 57_600);
        let occ_full = a.last_beat - a.first_beat;
        let occ_tenth = b.last_beat - b.first_beat;
        assert_eq!(occ_full, 1000);
        assert_eq!(occ_tenth, 10_000);
    }

    #[test]
    fn latency_knob_is_respected() {
        let mut d = Dram::with_params(1 << 12, GBPS_PIN_FULL, 88, 64);
        let t = d.schedule_read(0, 64);
        assert_eq!(t.first_beat, 64 + 88 + 64);
    }

    #[test]
    fn backing_store_roundtrip() {
        let mut d = Dram::new(1 << 12);
        d.poke_f64(16, -2.5);
        assert_eq!(d.peek_f64(16), -2.5);
        d.write_bytes(100, &[1, 2, 3]);
        assert_eq!(d.read_bytes(100, 3), &[1, 2, 3]);
    }

    #[test]
    fn stats_accumulate() {
        let mut d = Dram::new(1 << 12);
        d.schedule_read(0, 128);
        d.schedule_write(5, 64);
        assert_eq!(d.bytes_read, 128);
        assert_eq!(d.bytes_written, 64);
        assert_eq!(d.bursts, 2);
    }
}
