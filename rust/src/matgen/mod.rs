//! Deterministic workload generators standing in for the SuiteSparse
//! corpus (§4: "real-world-problem matrices ... 2k to 3.2k columns and
//! 2.8k to 543k nonzeros") and for the generated sparse/dense vectors.
//!
//! Where the paper's matrix has an exact deterministic construction we
//! build it bit-exactly (the Mycielskian graphs — `mycielskian12` is the
//! peak-speedup matrix of §4.2.1). The rest of the corpus is covered by
//! structurally similar generators: FEM stencils (banded, regular),
//! R-MAT power-law graphs (skewed degree), economics-style block
//! structure, and uniform random patterns, parameterized to span the
//! paper's n̄_nz (1..180) and size ranges.

use crate::formats::{Csr, SpVec};
use crate::util::Pcg;

/// Generate a sparse vector with `nnz` uniformly distributed positions
/// and normally distributed values (§4).
pub fn random_spvec(seed: u64, dim: usize, nnz: usize) -> SpVec {
    let mut r = Pcg::new(seed);
    let idcs: Vec<u32> = r.distinct_sorted(nnz, dim).iter().map(|&x| x as u32).collect();
    let vals: Vec<f64> = (0..nnz).map(|_| r.normal()).collect();
    SpVec::new(dim, idcs, vals)
}

/// Dense vector with normally distributed values.
pub fn random_dense(seed: u64, dim: usize) -> Vec<f64> {
    let mut r = Pcg::new(seed);
    (0..dim).map(|_| r.normal()).collect()
}

/// The Mycielski construction: `mycielskian(k)` is the graph M_k with
/// M_2 = K_2; |V(M_k)| = 3*2^(k-2) - 1. `mycielskian12` from SuiteSparse
/// is the adjacency matrix of M_12 (3071 nodes, 530 k nonzeros,
/// n̄_nz ≈ 173) — the paper's peak-speedup matrix. Values are set to 1.0
/// (adjacency) then jittered deterministically to avoid degenerate FP
/// behaviour.
pub fn mycielskian(k: u32) -> Csr {
    assert!((2..=12).contains(&k), "mycielskian order out of range");
    // adjacency list construction
    let mut edges: Vec<(u32, u32)> = vec![(0, 1)];
    let mut n: u32 = 2;
    for _ in 2..k {
        // vertices 0..n are U; add shadow W = n..2n and apex z = 2n.
        let mut new_edges = edges.clone();
        for &(a, b) in &edges {
            new_edges.push((a, b + n));
            new_edges.push((b, a + n));
        }
        for w in n..2 * n {
            new_edges.push((w, 2 * n));
        }
        edges = new_edges;
        n = 2 * n + 1;
    }
    let mut t = Vec::with_capacity(edges.len() * 2);
    for &(a, b) in &edges {
        // deterministic value from the edge id
        let v = 1.0 + 0.001 * ((a.wrapping_mul(31).wrapping_add(b) % 97) as f64);
        t.push((a, b, v));
        t.push((b, a, v));
    }
    Csr::from_triplets(n as usize, n as usize, t)
}

/// 5-point 2D Laplacian stencil on an `nx` x `ny` grid (FEM/PDE-style
/// SuiteSparse matrices: symmetric, banded, n̄_nz ≈ 5).
pub fn stencil2d(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut t = Vec::with_capacity(5 * n);
    let id = |x: usize, y: usize| (y * nx + x) as u32;
    for y in 0..ny {
        for x in 0..nx {
            let c = id(x, y);
            t.push((c, c, 4.0));
            if x > 0 {
                t.push((c, id(x - 1, y), -1.0));
            }
            if x + 1 < nx {
                t.push((c, id(x + 1, y), -1.0));
            }
            if y > 0 {
                t.push((c, id(x, y - 1), -1.0));
            }
            if y + 1 < ny {
                t.push((c, id(x, y + 1), -1.0));
            }
        }
    }
    Csr::from_triplets(n, n, t)
}

/// 27-point 3D stencil (higher n̄_nz ≈ 27 FEM-style).
pub fn stencil3d(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let mut t = Vec::with_capacity(27 * n);
    let id = |x: usize, y: usize, z: usize| ((z * ny + y) * nx + x) as u32;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let c = id(x, y, z);
                for dz in -1i64..=1 {
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let (xx, yy, zz) = (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                            if xx < 0 || yy < 0 || zz < 0
                                || xx >= nx as i64 || yy >= ny as i64 || zz >= nz as i64
                            {
                                continue;
                            }
                            let w = if (dx, dy, dz) == (0, 0, 0) { 26.0 } else { -1.0 };
                            t.push((c, id(xx as usize, yy as usize, zz as usize), w));
                        }
                    }
                }
            }
        }
    }
    Csr::from_triplets(n, n, t)
}

/// R-MAT power-law graph generator (skewed row lengths like web/social
/// graphs in SuiteSparse).
pub fn rmat(seed: u64, scale: u32, edge_factor: usize) -> Csr {
    let n = 1usize << scale;
    let n_edges = n * edge_factor;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut r = Pcg::new(seed);
    let mut t = Vec::with_capacity(n_edges);
    for _ in 0..n_edges {
        let (mut x, mut y) = (0usize, 0usize);
        for lvl in (0..scale).rev() {
            let p = r.f64();
            let (dx, dy) = if p < a {
                (0, 0)
            } else if p < a + b {
                (0, 1)
            } else if p < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            x |= dx << lvl;
            y |= dy << lvl;
        }
        t.push((x as u32, y as u32, 1.0 + r.f64()));
    }
    Csr::from_triplets(n, n, t)
}

/// A simple undirected graph for the §3.3 pattern-matching kernels:
/// R-MAT edges symmetrized, self-loops dropped, unit weights — the
/// adjacency is a symmetric zero-diagonal 0/1 pattern (what `tricnt`
/// requires).
pub fn undirected_graph(seed: u64, scale: u32, edge_factor: usize) -> Csr {
    let m = rmat(seed, scale, edge_factor);
    let mut t = Vec::with_capacity(2 * m.nnz());
    for r in 0..m.nrows {
        let (idx, _) = m.row(r);
        for &c in idx.iter().filter(|&&c| c as usize != r) {
            t.push((r as u32, c, 1.0));
            t.push((c, r as u32, 1.0));
        }
    }
    // parallel edges collapse to a single unit entry
    t.sort_unstable_by_key(|&(r, c, _)| (r, c));
    t.dedup_by_key(|e| (e.0, e.1));
    Csr::from_triplets(m.nrows, m.ncols, t)
}

/// Banded matrix with `band` diagonals each side (economics / circuit
/// style regularity).
pub fn banded(seed: u64, n: usize, band: usize) -> Csr {
    let mut r = Pcg::new(seed);
    let mut t = vec![];
    for i in 0..n {
        let lo = i.saturating_sub(band);
        let hi = (i + band + 1).min(n);
        for j in lo..hi {
            if i == j || r.f64() < 0.7 {
                t.push((i as u32, j as u32, r.normal()));
            }
        }
    }
    Csr::from_triplets(n, n, t)
}

/// Uniform random matrix with an exact global nonzero count.
pub fn random_csr(seed: u64, nrows: usize, ncols: usize, nnz: usize) -> Csr {
    let mut r = Pcg::new(seed);
    let cells = r.distinct_sorted(nnz, nrows * ncols);
    let t: Vec<(u32, u32, f64)> = cells
        .iter()
        .map(|&cell| {
            let (row, col) = ((cell as usize / ncols) as u32, (cell as usize % ncols) as u32);
            (row, col, r.normal())
        })
        .collect();
    Csr::from_triplets(nrows, ncols, t)
}

/// A named matrix of the evaluation corpus.
pub struct CorpusEntry {
    pub name: &'static str,
    pub matrix: Csr,
}

/// The evaluation corpus: spans the paper's column range (2k–3.2k),
/// nnz range (2.8k–543k) and n̄_nz range (~1–173), mixing exact
/// SuiteSparse reconstructions (Mycielskians) with structural stand-ins.
/// Sorted by average row nonzeros (the x-axis of Figs. 4c/4f/5a).
pub fn corpus() -> Vec<CorpusEntry> {
    let mut v = vec![
        // n̄_nz ~ 1: ultra-sparse permutation-like (economics flow)
        CorpusEntry { name: "perm3000", matrix: random_csr(11, 3000, 3000, 3000) },
        CorpusEntry { name: "rand2k_6k", matrix: random_csr(12, 2048, 2048, 6144) },
        // FEM 2D: n̄_nz ~ 5 (cryg2500-like: 2500 cols, 12.3k nnz)
        CorpusEntry { name: "cryg2500", matrix: stencil2d(50, 50) },
        CorpusEntry { name: "fem2d_56", matrix: stencil2d(56, 56) },
        // power-law graphs: n̄_nz ~ 8–16, skewed
        CorpusEntry { name: "rmat11_8", matrix: rmat(13, 11, 8) },
        CorpusEntry { name: "rmat11_16", matrix: rmat(14, 11, 16) },
        // banded/circuit: n̄_nz ~ 14
        CorpusEntry { name: "band3000_10", matrix: banded(15, 3000, 10) },
        // FEM 3D: n̄_nz ~ 24 (cavity12-like density)
        CorpusEntry { name: "cavity12", matrix: stencil3d(14, 14, 14) },
        CorpusEntry { name: "fem3d_13", matrix: stencil3d(13, 13, 13) },
        // dense-ish random: n̄_nz ~ 32, 64
        CorpusEntry { name: "rand2k_64k", matrix: random_csr(16, 2048, 2048, 65536) },
        CorpusEntry { name: "rand2k_128k", matrix: random_csr(17, 2048, 2048, 131072) },
        // Mycielskian graphs (exact SuiteSparse constructions)
        CorpusEntry { name: "mycielskian9", matrix: mycielskian(9) },
        CorpusEntry { name: "mycielskian10", matrix: mycielskian(10) },
        CorpusEntry { name: "mycielskian11", matrix: mycielskian(11) },
        CorpusEntry { name: "mycielskian12", matrix: mycielskian(12) },
    ];
    v.sort_by(|a, b| a.matrix.avg_row_nnz().partial_cmp(&b.matrix.avg_row_nnz()).unwrap());
    v
}

/// The tiny `Ragusa18` matrix (§3.2.1 edge case: 64 nonzeros): a small
/// directed-graph matrix stand-in with the published dimensions.
pub fn ragusa18() -> Csr {
    random_csr(18, 23, 23, 64)
}

/// Parse a Matrix Market *coordinate* matrix (the SuiteSparse download
/// format), so real corpus matrices can replace the deterministic
/// stand-ins. Supports the `real` / `integer` / `pattern` fields and
/// `general` / `symmetric` / `skew-symmetric` symmetries; `pattern`
/// entries get value 1.0 and symmetric off-diagonals are mirrored.
/// Duplicate entries are summed (as [`Csr::from_triplets`] does).
pub fn parse_mtx(text: &str) -> Result<Csr, String> {
    let mut lines = text.lines();
    let header = lines.next().ok_or("empty .mtx file")?;
    let h: Vec<String> = header.split_whitespace().map(str::to_ascii_lowercase).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(format!("not a MatrixMarket matrix header: {header:?}"));
    }
    if h[2] != "coordinate" {
        return Err(format!("unsupported format {:?} (only coordinate)", h[2]));
    }
    let pattern = match h[3].as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        f => return Err(format!("unsupported field {f:?} (real/integer/pattern)")),
    };
    let (mirror, skew) = match h[4].as_str() {
        "general" => (false, false),
        "symmetric" => (true, false),
        "skew-symmetric" => (true, true),
        s => return Err(format!("unsupported symmetry {s:?}")),
    };

    let mut dims: Option<(usize, usize, usize)> = None;
    let mut t: Vec<(u32, u32, f64)> = vec![];
    let mut stored = 0usize;
    for (lineno, line) in lines.enumerate() {
        let s = line.trim();
        if s.is_empty() || s.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = s.split_whitespace().collect();
        match dims {
            None => {
                if toks.len() < 3 {
                    return Err(format!("line {}: expected 'nrows ncols nnz'", lineno + 2));
                }
                let nrows: usize = toks[0].parse().map_err(|e| format!("nrows: {e}"))?;
                let ncols: usize = toks[1].parse().map_err(|e| format!("ncols: {e}"))?;
                let nnz: usize = toks[2].parse().map_err(|e| format!("nnz: {e}"))?;
                dims = Some((nrows, ncols, nnz));
                t.reserve(if mirror { 2 * nnz } else { nnz });
            }
            Some((nrows, ncols, _)) => {
                let need = if pattern { 2 } else { 3 };
                if toks.len() < need {
                    return Err(format!("line {}: expected {need} fields", lineno + 2));
                }
                let r: usize = toks[0].parse().map_err(|e| format!("row: {e}"))?;
                let c: usize = toks[1].parse().map_err(|e| format!("col: {e}"))?;
                let v: f64 = if pattern {
                    1.0
                } else {
                    toks[2].parse().map_err(|e| format!("value: {e}"))?
                };
                if !(1..=nrows).contains(&r) || !(1..=ncols).contains(&c) {
                    return Err(format!(
                        "line {}: entry ({r},{c}) outside {nrows}x{ncols}",
                        lineno + 2
                    ));
                }
                stored += 1;
                t.push((r as u32 - 1, c as u32 - 1, v));
                if mirror && r != c {
                    // the mirrored entry (c,r) must be in bounds too —
                    // a symmetric declaration with nrows != ncols can
                    // pass the raw check above yet mirror out of range
                    if !(1..=nrows).contains(&c) || !(1..=ncols).contains(&r) {
                        return Err(format!(
                            "line {}: mirrored entry ({c},{r}) outside {nrows}x{ncols}",
                            lineno + 2
                        ));
                    }
                    t.push((c as u32 - 1, r as u32 - 1, if skew { -v } else { v }));
                }
            }
        }
    }
    let (nrows, ncols, nnz) = dims.ok_or("missing dimensions line")?;
    if stored != nnz {
        return Err(format!("header declares {nnz} entries, file has {stored}"));
    }
    Ok(Csr::from_triplets(nrows, ncols, t))
}

/// Load a `.mtx` file from disk via [`parse_mtx`].
pub fn load_mtx(path: &std::path::Path) -> Result<Csr, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_mtx(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mycielskian_sizes_match_theory() {
        // |V(M_k)| = 3 * 2^(k-2) - 1
        for k in 2..=12u32 {
            let m = mycielskian(k);
            let want = 3 * (1usize << (k - 2)) - 1;
            assert_eq!(m.nrows, want, "M_{k}");
        }
    }

    #[test]
    fn mycielskian12_matches_suitesparse_stats() {
        // §4.2.1: mycielskian12 has n̄_nz = 133 and 4.3 % density.
        // |E(M_k)| = 3|E(M_{k-1})| + |V(M_{k-1})| gives 203,600 edges
        // -> 407,200 stored nonzeros over 3071 rows.
        let m = mycielskian(12);
        assert_eq!(m.nrows, 3071);
        assert_eq!(m.nnz(), 407_200);
        let nnz_row = m.avg_row_nnz();
        assert!((132.0..134.0).contains(&nnz_row), "n̄_nz {nnz_row}");
        let d = m.density();
        assert!((0.042..0.045).contains(&d), "density {d}");
    }

    #[test]
    fn mycielskian_is_symmetric_pattern() {
        let m = mycielskian(6);
        let t = m.transpose();
        assert_eq!(m.idcs, t.idcs);
        assert_eq!(m.ptrs, t.ptrs);
    }

    #[test]
    fn mycielskian_triangle_free() {
        // Mycielski graphs are triangle-free by construction.
        let m = mycielskian(7);
        let d = m.to_dense();
        let n = m.nrows;
        for a in 0..n {
            for b in (a + 1)..n {
                if d[a][b] == 0.0 {
                    continue;
                }
                for c in (b + 1)..n {
                    assert!(
                        d[a][c] == 0.0 || d[b][c] == 0.0,
                        "triangle {a},{b},{c} found"
                    );
                }
            }
        }
    }

    #[test]
    fn stencil2d_row_counts() {
        let m = stencil2d(10, 10);
        assert_eq!(m.nrows, 100);
        // interior rows have 5 nonzeros, corners 3
        let (i, _) = m.row(5 * 10 + 5);
        assert_eq!(i.len(), 5);
        let (c, _) = m.row(0);
        assert_eq!(c.len(), 3);
        m.validate().unwrap();
    }

    #[test]
    fn stencil3d_interior_has_27() {
        let m = stencil3d(5, 5, 5);
        let center = (2 * 5 + 2) * 5 + 2;
        let (i, _) = m.row(center);
        assert_eq!(i.len(), 27);
    }

    #[test]
    fn rmat_is_skewed() {
        let m = rmat(7, 10, 8);
        let rows: Vec<usize> = (0..m.nrows).map(|r| m.row(r).0.len()).collect();
        let max = *rows.iter().max().unwrap();
        let mean = rows.iter().sum::<usize>() as f64 / rows.len() as f64;
        assert!(max as f64 > 5.0 * mean, "max {max} vs mean {mean}: not skewed");
    }

    #[test]
    fn random_csr_exact_nnz() {
        let m = random_csr(3, 100, 200, 999);
        assert_eq!(m.nnz(), 999);
        m.validate().unwrap();
    }

    #[test]
    fn random_spvec_deterministic() {
        let a = random_spvec(5, 1000, 50);
        let b = random_spvec(5, 1000, 50);
        assert_eq!(a, b);
        assert_eq!(a.nnz(), 50);
        a.validate().unwrap();
    }

    /// Embedded fixture: a 4x5 general real matrix in SuiteSparse
    /// download format, with comments and blank lines.
    const FIXTURE_GENERAL: &str = "\
%%MatrixMarket matrix coordinate real general
% generated fixture
% rows cols nnz

4 5 6
1 1 2.5
1 4 -1.0
2 2 3.25
3 5 4.0
4 1 -0.5
4 4 1.5
";

    const FIXTURE_SYMMETRIC: &str = "\
%%MatrixMarket matrix coordinate real symmetric
3 3 4
1 1 1.0
2 1 2.0
3 2 3.0
3 3 4.0
";

    const FIXTURE_PATTERN: &str = "\
%%MatrixMarket matrix coordinate pattern general
2 2 2
1 2
2 1
";

    #[test]
    fn parse_mtx_general_fixture() {
        let m = parse_mtx(FIXTURE_GENERAL).unwrap();
        assert_eq!((m.nrows, m.ncols, m.nnz()), (4, 5, 6));
        let d = m.to_dense();
        assert_eq!(d[0][0], 2.5);
        assert_eq!(d[0][3], -1.0);
        assert_eq!(d[1][1], 3.25);
        assert_eq!(d[2][4], 4.0);
        assert_eq!(d[3][0], -0.5);
        assert_eq!(d[3][3], 1.5);
        m.validate().unwrap();
    }

    #[test]
    fn parse_mtx_symmetric_mirrors_off_diagonals() {
        let m = parse_mtx(FIXTURE_SYMMETRIC).unwrap();
        assert_eq!(m.nnz(), 6); // 2 diagonal + 2 mirrored pairs
        let d = m.to_dense();
        assert_eq!(d[1][0], 2.0);
        assert_eq!(d[0][1], 2.0);
        assert_eq!(d[2][1], 3.0);
        assert_eq!(d[1][2], 3.0);
        assert_eq!(d[0][0], 1.0);
        assert_eq!(d[2][2], 4.0);
    }

    #[test]
    fn parse_mtx_pattern_gets_unit_values() {
        let m = parse_mtx(FIXTURE_PATTERN).unwrap();
        assert_eq!(m.to_dense(), vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
    }

    #[test]
    fn parse_mtx_rejects_bad_input() {
        assert!(parse_mtx("").is_err());
        assert!(parse_mtx("%%MatrixMarket matrix array real general\n2 2\n1.0\n").is_err());
        assert!(parse_mtx("%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 0\n")
            .is_err());
        // out-of-range entry
        assert!(parse_mtx("%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n")
            .is_err());
        // count mismatch vs header
        assert!(parse_mtx("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n")
            .is_err());
    }

    #[test]
    fn parse_mtx_rejects_out_of_bounds_mirror() {
        // (3,1) is in bounds of the declared 3x2 shape, but its mirror
        // (1,3) is not: must be a clean error, not a panic downstream
        let bad = "%%MatrixMarket matrix coordinate real symmetric\n3 2 1\n3 1 1.0\n";
        let err = parse_mtx(bad).unwrap_err();
        assert!(err.contains("mirrored"), "unexpected error: {err}");
        // the same entry under `general` symmetry stays valid
        let ok = "%%MatrixMarket matrix coordinate real general\n3 2 1\n3 1 1.0\n";
        assert_eq!(parse_mtx(ok).unwrap().to_dense()[2][0], 1.0);
        // square symmetric mirroring is unaffected by the new check
        assert_eq!(parse_mtx(FIXTURE_SYMMETRIC).unwrap().nnz(), 6);
    }

    #[test]
    fn undirected_graph_is_simple_symmetric() {
        for seed in [1u64, 2, 3] {
            let g = undirected_graph(seed, 7, 4);
            g.validate().unwrap();
            assert_eq!(g.nrows, 128);
            let t = g.transpose();
            assert_eq!((&g.ptrs, &g.idcs), (&t.ptrs, &t.idcs), "not symmetric");
            for r in 0..g.nrows {
                let (idx, val) = g.row(r);
                assert!(!idx.contains(&(r as u32)), "self-loop at {r}");
                assert!(val.iter().all(|&v| v == 1.0), "non-unit weight");
            }
        }
    }

    #[test]
    fn load_mtx_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join("sssr_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fixture.mtx");
        std::fs::write(&path, FIXTURE_GENERAL).unwrap();
        let m = load_mtx(&path).unwrap();
        assert_eq!(m, parse_mtx(FIXTURE_GENERAL).unwrap());
        assert!(load_mtx(&dir.join("missing.mtx")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corpus_spans_paper_ranges() {
        let c = corpus();
        assert!(c.len() >= 12);
        let n_nz: Vec<f64> = c.iter().map(|e| e.matrix.avg_row_nnz()).collect();
        assert!(n_nz.first().unwrap() < &3.0);
        assert!(n_nz.last().unwrap() > &100.0);
        // sorted ascending
        for w in n_nz.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for e in &c {
            e.matrix.validate().unwrap();
            // the paper's corpus is 2k–3.2k columns; the smaller
            // Mycielskians extend the sweep to lower n̄_nz.
            assert!(e.matrix.ncols >= 300 && e.matrix.ncols <= 4096, "{}", e.name);
        }
    }
}
