//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§4–§5). Each `figNx()` function runs the corresponding
//! experiment on the simulator and returns printable rows; the bench
//! targets under `rust/benches/` and the `repro` CLI both call in here.
//!
//! Sweep sizes: the default ("quick") configuration subsamples the
//! corpus and caps matrix sizes so `cargo bench` completes in minutes;
//! set `REPRO_FULL=1` for the full corpus (including mycielskian12's
//! 407 k stored nonzeros).

use crate::coordinator::{run_cluster_smxdv, run_cluster_smxsv};
use crate::formats::SpVec;
use crate::kernels::driver::{
    run_smxdv_sized, run_smxsv_sized, run_svpdv, run_svpsv, run_svxdv, run_svxsv,
};
use crate::kernels::{IdxWidth, Variant};
use crate::matgen;
use crate::model::energy::EnergyModel;
use crate::model::{streamer_area, streamer_min_period_ps, SlotKind, StreamerCfg};
use crate::sim::ClusterCfg;

/// Enlarged single-CC TCDM for the §4.1 "matrix fits the TCDM" runs.
pub const BIG_TCDM: usize = 16 << 20;

pub fn full_mode() -> bool {
    std::env::var("REPRO_FULL").map(|v| v == "1").unwrap_or(false)
}

fn corpus_selection() -> Vec<matgen::CorpusEntry> {
    let all = matgen::corpus();
    if full_mode() {
        all
    } else {
        // quick: subsample across the n̄_nz range, cap nnz for wall time
        all.into_iter()
            .filter(|e| e.matrix.nnz() <= 140_000)
            .enumerate()
            .filter(|(i, _)| i % 2 == 0 || *i < 4)
            .map(|(_, e)| e)
            .collect()
    }
}

// ======================================================================
// Fig. 4a/4b — single-CC sV×dV / sV+dV FPU utilization vs nonzeros
// ======================================================================

#[derive(Clone, Debug)]
pub struct UtilRow {
    pub variant: &'static str,
    pub nnz: usize,
    pub utilization: f64,
    /// Without reductions (dashed series; sV×dV SSSR only).
    pub utilization_nored: Option<f64>,
}

fn nnz_sweep() -> Vec<usize> {
    if full_mode() {
        vec![4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096]
    } else {
        vec![4, 16, 64, 256, 1024, 4096]
    }
}

/// A fiber with *repeated* 8-bit indices (the `sssr8r` series: "8-bit
/// indirection with repeated indices", §4.1.1).
fn repeated_idx_fiber(seed: u64, dim: usize, nnz: usize) -> SpVec {
    let mut r = crate::util::Pcg::new(seed);
    let mut idcs: Vec<u32> = (0..nnz).map(|_| r.below(dim as u64) as u32).collect();
    idcs.sort_unstable();
    let vals = (0..nnz).map(|_| r.normal()).collect();
    SpVec { dim, idcs, vals }
}

pub fn fig4a() -> Vec<UtilRow> {
    let dim16 = 8192; // dense operand resident in the TCDM
    let dim8 = 256;
    let b16 = matgen::random_dense(101, dim16);
    let b8 = matgen::random_dense(102, dim8);
    let mut rows = vec![];
    for &nnz in &nnz_sweep() {
        let a16 = matgen::random_spvec(200 + nnz as u64, dim16, nnz);
        // BASE and SSR perform identically for all index sizes (§4.1.1)
        let (_, r) = run_svxdv(Variant::Base, IdxWidth::U16, &a16, &b16, false);
        rows.push(UtilRow { variant: "base", nnz, utilization: r.utilization, utilization_nored: None });
        let (_, r) = run_svxdv(Variant::Ssr, IdxWidth::U16, &a16, &b16, false);
        rows.push(UtilRow { variant: "ssr", nnz, utilization: r.utilization, utilization_nored: None });
        for (name, iw) in [("sssr16", IdxWidth::U16), ("sssr32", IdxWidth::U32)] {
            let (_, with) = run_svxdv(Variant::Sssr, iw, &a16, &b16, false);
            let (_, wo) = run_svxdv(Variant::Sssr, iw, &a16, &b16, true);
            rows.push(UtilRow {
                variant: name,
                nnz,
                utilization: with.utilization,
                utilization_nored: Some(wo.utilization),
            });
        }
        if nnz <= dim8 {
            let a8 = matgen::random_spvec(300 + nnz as u64, dim8, nnz);
            let (_, with) = run_svxdv(Variant::Sssr, IdxWidth::U8, &a8, &b8, false);
            let (_, wo) = run_svxdv(Variant::Sssr, IdxWidth::U8, &a8, &b8, true);
            rows.push(UtilRow {
                variant: "sssr8",
                nnz,
                utilization: with.utilization,
                utilization_nored: Some(wo.utilization),
            });
        }
        // repeated 8-bit indices scale past 256 nonzeros
        let a8r = repeated_idx_fiber(400 + nnz as u64, dim8, nnz);
        let (_, with) = run_svxdv(Variant::Sssr, IdxWidth::U8, &a8r, &b8, false);
        let (_, wo) = run_svxdv(Variant::Sssr, IdxWidth::U8, &a8r, &b8, true);
        rows.push(UtilRow {
            variant: "sssr8r",
            nnz,
            utilization: with.utilization,
            utilization_nored: Some(wo.utilization),
        });
    }
    rows
}

pub fn fig4b() -> Vec<UtilRow> {
    let dim16 = 8192;
    let dim8 = 256;
    let b16 = matgen::random_dense(111, dim16);
    let b8 = matgen::random_dense(112, dim8);
    let mut rows = vec![];
    for &nnz in &nnz_sweep() {
        let a16 = matgen::random_spvec(500 + nnz as u64, dim16, nnz);
        for (name, v, iw) in [
            ("base", Variant::Base, IdxWidth::U16),
            ("ssr", Variant::Ssr, IdxWidth::U16),
            ("sssr16", Variant::Sssr, IdxWidth::U16),
            ("sssr32", Variant::Sssr, IdxWidth::U32),
        ] {
            let (_, r) = run_svpdv(v, iw, &a16, &b16);
            rows.push(UtilRow { variant: name, nnz, utilization: r.utilization, utilization_nored: None });
        }
        // timing-only: repeated indices make the in-place update
        // order-dependent (see run_svpdv_unchecked)
        let a8r = repeated_idx_fiber(600 + nnz as u64, dim8, nnz);
        let (_, r) = crate::kernels::driver::run_svpdv_unchecked(Variant::Sssr, IdxWidth::U8, &a8r, &b8);
        rows.push(UtilRow { variant: "sssr8r", nnz, utilization: r.utilization, utilization_nored: None });
    }
    rows
}

// ======================================================================
// Fig. 4c — single-CC sM×dV speedups over BASE per matrix
// ======================================================================

#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub matrix: String,
    pub avg_row_nnz: f64,
    pub variant: &'static str,
    pub speedup: f64,
    pub utilization: f64,
}

pub fn fig4c() -> Vec<SpeedupRow> {
    let mut rows = vec![];
    for e in corpus_selection() {
        let b = matgen::random_dense(700, e.matrix.ncols);
        let (_, base) = run_smxdv_sized(Variant::Base, IdxWidth::U16, &e.matrix, &b, BIG_TCDM);
        for (name, v, iw) in [
            ("ssr", Variant::Ssr, IdxWidth::U16),
            ("sssr16", Variant::Sssr, IdxWidth::U16),
            ("sssr32", Variant::Sssr, IdxWidth::U32),
        ] {
            let (_, r) = run_smxdv_sized(v, iw, &e.matrix, &b, BIG_TCDM);
            rows.push(SpeedupRow {
                matrix: e.name.to_string(),
                avg_row_nnz: e.matrix.avg_row_nnz(),
                variant: name,
                speedup: base.cycles as f64 / r.cycles as f64,
                utilization: r.utilization,
            });
        }
    }
    rows
}

// ======================================================================
// Fig. 4d/4e — single-CC sV×sV / sV+sV speedups vs operand densities
// ======================================================================

#[derive(Clone, Debug)]
pub struct DensityRow {
    pub density_a: f64,
    pub density_b: f64,
    pub speedup: f64,
}

fn density_sweep() -> Vec<f64> {
    if full_mode() {
        vec![0.0003, 0.001, 0.003, 0.01, 0.03, 0.1, 0.3]
    } else {
        vec![0.001, 0.01, 0.1, 0.3]
    }
}

/// Shared sweep for the sparse-sparse vector kernels. The paper uses
/// dense size 60k; quick mode uses 20k (same density semantics, smaller
/// wall time).
fn svv_sweep(which: &str) -> Vec<DensityRow> {
    let dim = if full_mode() { 60_000 } else { 20_000 };
    let mut rows = vec![];
    for &da in &density_sweep() {
        for &db in &density_sweep() {
            let na = ((da * dim as f64) as usize).max(1);
            let nb = ((db * dim as f64) as usize).max(1);
            let a = matgen::random_spvec(800 + na as u64, dim, na);
            let b = matgen::random_spvec(900 + nb as u64, dim, nb);
            let (base, sssr) = match which {
                "svxsv" => {
                    let (_, x) = run_svxsv(Variant::Base, IdxWidth::U32, &a, &b);
                    let (_, y) = run_svxsv(Variant::Sssr, IdxWidth::U32, &a, &b);
                    (x, y)
                }
                "svpsv" => {
                    let (_, x) = run_svpsv(Variant::Base, IdxWidth::U32, &a, &b);
                    let (_, y) = run_svpsv(Variant::Sssr, IdxWidth::U32, &a, &b);
                    (x, y)
                }
                _ => unreachable!(),
            };
            rows.push(DensityRow {
                density_a: da,
                density_b: db,
                speedup: base.cycles as f64 / sssr.cycles as f64,
            });
        }
    }
    rows
}

pub fn fig4d() -> Vec<DensityRow> {
    svv_sweep("svxsv")
}

pub fn fig4e() -> Vec<DensityRow> {
    svv_sweep("svpsv")
}

// ======================================================================
// Fig. 4f — single-CC sM×sV speedups per matrix and vector density
// ======================================================================

#[derive(Clone, Debug)]
pub struct MatSvRow {
    pub matrix: String,
    pub avg_row_nnz: f64,
    pub density: f64,
    pub speedup: f64,
}

pub fn fig4f() -> Vec<MatSvRow> {
    let densities = if full_mode() { vec![0.001, 0.01, 0.1, 0.3] } else { vec![0.01, 0.3] };
    let mut rows = vec![];
    for e in corpus_selection() {
        for &dv in &densities {
            let nnz = ((dv * e.matrix.ncols as f64) as usize).max(1);
            let b = matgen::random_spvec(1000 + nnz as u64, e.matrix.ncols, nnz);
            let (_, base) = run_smxsv_sized(Variant::Base, IdxWidth::U16, &e.matrix, &b, BIG_TCDM);
            let (_, sssr) = run_smxsv_sized(Variant::Sssr, IdxWidth::U16, &e.matrix, &b, BIG_TCDM);
            rows.push(MatSvRow {
                matrix: e.name.to_string(),
                avg_row_nnz: e.matrix.avg_row_nnz(),
                density: dv,
                speedup: base.cycles as f64 / sssr.cycles as f64,
            });
        }
    }
    rows
}

// ======================================================================
// Fig. 5a/5b — eight-core cluster speedups (HBM + interconnect models)
// ======================================================================

#[derive(Clone, Debug)]
pub struct ClusterRow {
    pub matrix: String,
    pub avg_row_nnz: f64,
    pub density: f64,
    pub speedup: f64,
    pub utilization: f64,
    pub base_cycles: u64,
    pub sssr_cycles: u64,
}

pub fn fig5a() -> Vec<ClusterRow> {
    let cfg = ClusterCfg::paper_cluster();
    let mut rows = vec![];
    for e in corpus_selection() {
        let b = matgen::random_dense(1100, e.matrix.ncols);
        let base = run_cluster_smxdv(Variant::Base, IdxWidth::U16, &e.matrix, &b, &cfg);
        let sssr = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &e.matrix, &b, &cfg);
        rows.push(ClusterRow {
            matrix: e.name.to_string(),
            avg_row_nnz: e.matrix.avg_row_nnz(),
            density: 1.0,
            speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
            utilization: sssr.report.payload as f64 / (sssr.report.cycles as f64 * cfg.cores as f64),
            base_cycles: base.report.cycles,
            sssr_cycles: sssr.report.cycles,
        });
    }
    rows
}

pub fn fig5b() -> Vec<ClusterRow> {
    let cfg = ClusterCfg::paper_cluster();
    let densities = if full_mode() { vec![0.001, 0.01, 0.1, 0.3] } else { vec![0.01, 0.3] };
    let mut rows = vec![];
    for e in corpus_selection() {
        for &dv in &densities {
            let nnz = ((dv * e.matrix.ncols as f64) as usize).max(1);
            let b = matgen::random_spvec(1200 + nnz as u64, e.matrix.ncols, nnz);
            let base = run_cluster_smxsv(Variant::Base, IdxWidth::U16, &e.matrix, &b, &cfg);
            let sssr = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &e.matrix, &b, &cfg);
            rows.push(ClusterRow {
                matrix: e.name.to_string(),
                avg_row_nnz: e.matrix.avg_row_nnz(),
                density: dv,
                speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
                utilization: sssr.report.payload as f64
                    / (sssr.report.cycles as f64 * cfg.cores as f64),
                base_cycles: base.report.cycles,
                sssr_cycles: sssr.report.cycles,
            });
        }
    }
    rows
}

// ======================================================================
// Fig. 6 — bandwidth / latency sensitivity
// ======================================================================

#[derive(Clone, Debug)]
pub struct SensitivityRow {
    pub x: f64, // Gb/s/pin or cycles
    pub kernel: &'static str,
    pub speedup: f64,
}

/// The paper uses its peak-speedup matrix mycielskian12 here; quick mode
/// uses mycielskian11 (same construction, quarter size).
fn fig6_matrix() -> crate::formats::Csr {
    if full_mode() {
        matgen::mycielskian(12)
    } else {
        matgen::mycielskian(11)
    }
}

pub fn fig6a() -> Vec<SensitivityRow> {
    let m = fig6_matrix();
    let b = matgen::random_dense(1300, m.ncols);
    let dv = 0.01;
    let sv = matgen::random_spvec(1301, m.ncols, ((dv * m.ncols as f64) as usize).max(1));
    let mut rows = vec![];
    let bws = if full_mode() {
        vec![3.6, 2.4, 1.6, 1.2, 0.8, 0.6, 0.4]
    } else {
        vec![3.6, 1.6, 0.8, 0.4]
    };
    for &bw in &bws {
        let cfg = ClusterCfg { dram_gbps_pin: bw, ..ClusterCfg::paper_cluster() };
        let base = run_cluster_smxdv(Variant::Base, IdxWidth::U16, &m, &b, &cfg);
        let sssr = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg);
        rows.push(SensitivityRow {
            x: bw,
            kernel: "smxdv",
            speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
        });
        let base = run_cluster_smxsv(Variant::Base, IdxWidth::U16, &m, &sv, &cfg);
        let sssr = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &m, &sv, &cfg);
        rows.push(SensitivityRow {
            x: bw,
            kernel: "smxsv",
            speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
        });
    }
    rows
}

pub fn fig6b() -> Vec<SensitivityRow> {
    let m = fig6_matrix();
    let b = matgen::random_dense(1400, m.ncols);
    let dv = 0.01;
    let sv = matgen::random_spvec(1401, m.ncols, ((dv * m.ncols as f64) as usize).max(1));
    let mut rows = vec![];
    let lats: Vec<u64> = if full_mode() {
        vec![0, 16, 32, 64, 128, 256, 512]
    } else {
        vec![0, 16, 64, 256]
    };
    for &lat in &lats {
        let cfg = ClusterCfg { ic_latency: lat, ..ClusterCfg::paper_cluster() };
        let base = run_cluster_smxdv(Variant::Base, IdxWidth::U16, &m, &b, &cfg);
        let sssr = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg);
        rows.push(SensitivityRow {
            x: lat as f64,
            kernel: "smxdv",
            speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
        });
        let base = run_cluster_smxsv(Variant::Base, IdxWidth::U16, &m, &sv, &cfg);
        let sssr = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &m, &sv, &cfg);
        rows.push(SensitivityRow {
            x: lat as f64,
            kernel: "smxsv",
            speedup: base.report.cycles as f64 / sssr.report.cycles as f64,
        });
    }
    rows
}

// ======================================================================
// Fig. 7 — area and timing (analytical model)
// ======================================================================

#[derive(Clone, Debug)]
pub struct AreaRow {
    pub config: String,
    pub area_kge: f64,
    pub min_period_ps: f64,
}

pub fn fig7_configs() -> Vec<AreaRow> {
    use SlotKind::*;
    let configs: Vec<(&str, StreamerCfg)> = vec![
        ("S+S+S (baseline)", StreamerCfg::baseline_ssr()),
        ("I+S+S", StreamerCfg { slots: vec![Issr, Ssr, Ssr], union: false }),
        ("I+I+S", StreamerCfg { slots: vec![Issr, Issr, Ssr], union: false }),
        ("I*+I*+S", StreamerCfg { slots: vec![IssrCmp, IssrCmp, Ssr], union: false }),
        ("I*+I*+E", StreamerCfg { slots: vec![IssrCmp, IssrCmp, Essr], union: false }),
        ("I*+I*+E+union (default)", StreamerCfg::default_sssr()),
    ];
    configs
        .into_iter()
        .map(|(name, cfg)| AreaRow {
            config: name.to_string(),
            area_kge: streamer_area(&cfg),
            min_period_ps: streamer_min_period_ps(&cfg),
        })
        .collect()
}

#[derive(Clone, Debug)]
pub struct AreaPeriodRow {
    pub target_ps: f64,
    pub area_kge: f64,
}

pub fn fig7_area_vs_period() -> Vec<AreaPeriodRow> {
    let cfg = StreamerCfg::default_sssr();
    [450.0, 500.0, 550.0, 600.0, 700.0, 800.0, 1000.0]
        .iter()
        .map(|&t| AreaPeriodRow {
            target_ps: t,
            area_kge: crate::model::area::streamer_area_at_period(&cfg, t),
        })
        .collect()
}

// ======================================================================
// Fig. 8 — energy (activity-scaled model over cluster runs)
// ======================================================================

#[derive(Clone, Debug)]
pub struct EnergyRow {
    pub matrix: String,
    pub kernel: &'static str,
    pub variant: &'static str,
    pub pj_per_op: f64,
    pub power_mw: f64,
    pub total_uj: f64,
}

pub fn fig8(kernel: &'static str) -> Vec<EnergyRow> {
    let cfg = ClusterCfg::paper_cluster();
    let em = EnergyModel::default();
    let mut rows = vec![];
    for e in corpus_selection() {
        let runs: Vec<(&'static str, crate::coordinator::ClusterRun, u64)> = match kernel {
            "smxdv" => {
                let b = matgen::random_dense(1500, e.matrix.ncols);
                let base = run_cluster_smxdv(Variant::Base, IdxWidth::U16, &e.matrix, &b, &cfg);
                let sssr = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &e.matrix, &b, &cfg);
                let nnz = e.matrix.nnz() as u64;
                vec![("base", base, nnz), ("sssr", sssr, nnz)]
            }
            "smxsv" => {
                let nnz_v = ((0.01 * e.matrix.ncols as f64) as usize).max(1);
                let b = matgen::random_spvec(1600, e.matrix.ncols, nnz_v);
                let base = run_cluster_smxsv(Variant::Base, IdxWidth::U16, &e.matrix, &b, &cfg);
                let sssr = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &e.matrix, &b, &cfg);
                // Fig. 8b normalizes per *matrix nonzero*
                let nnz = e.matrix.nnz() as u64;
                vec![("base", base, nnz), ("sssr", sssr, nnz)]
            }
            _ => unreachable!(),
        };
        for (variant, run, ops) in runs {
            let er = em.estimate(&run.report.stats, ops);
            rows.push(EnergyRow {
                matrix: e.name.to_string(),
                kernel,
                variant,
                pj_per_op: er.pj_per_op,
                power_mw: er.avg_power_w * 1e3,
                total_uj: er.total_j * 1e6,
            });
        }
    }
    rows
}

// ======================================================================
// Tables 2 & 3 — comparisons against the literature
// ======================================================================

/// Literature rows of Table 2 (peak FP64 sM×dV utilization).
pub const TABLE2_LITERATURE: &[(&str, &str, &str, f64)] = &[
    ("CVR [33]", "Xeon Phi 7250", "CVR", 0.0069),
    ("Zhang et al. [34]", "Xeon Phi 7230", "SELL-like", 0.015),
    ("Regu2D [35]", "Xeon Gold 6132", "Regu2D", 0.031),
    ("Alappat et al. [7]", "A64FX", "SELL-C-sigma", 0.047),
    ("Tsai et al. [37]", "V100", "CSR", 0.016),
    ("Merrill et al. [38]", "K40", "CSR", 0.020),
    ("TileSpMV [39]", "A100", "tile-adapt.", 0.029),
    ("Tsai et al. [37]", "Radeon VII", "CSR", 0.032),
    ("cuSPARSE [40]", "GTX 1080 Ti", "CSR", 0.17),
    ("TileSpMV [39]", "Titan RTX", "tile-adapt.", 0.27),
];

/// Our measured peak cluster sM×dV utilization (Table 2 bottom row):
/// best over the corpus sweep.
pub fn table2_ours(fig5a_rows: &[ClusterRow]) -> f64 {
    fig5a_rows.iter().map(|r| r.utilization).fold(0.0, f64::max)
}

/// Table 3 hardware-design comparison (qualitative features from the
/// paper + our modeled area).
pub struct Table3Row {
    pub work: &'static str,
    pub open_source: bool,
    pub one_sided: bool,
    pub two_sided: bool,
    pub format_flex: &'static str,
    pub sparsity_flex: &'static str,
    pub area_kge: Option<f64>,
}

pub fn table3() -> Vec<Table3Row> {
    let ours_area = streamer_area(&StreamerCfg::default_sssr());
    vec![
        Table3Row { work: "SVE S/G [29]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: None },
        Table3Row { work: "KNL S/G [30]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: None },
        Table3Row { work: "UVE [31]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: Some(72.0) },
        Table3Row { work: "Gong et al. [32]", open_source: false, one_sided: true, two_sided: false, format_flex: "L", sparsity_flex: "L", area_kge: Some(31.0) },
        Table3Row { work: "Prodigy [8]", open_source: true, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: Some(10.0) },
        Table3Row { work: "SpZip [41]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: Some(116.0) },
        Table3Row { work: "Z. Wang et al. [9]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "H", area_kge: None },
        Table3Row { work: "SparseCore [6]", open_source: false, one_sided: false, two_sided: true, format_flex: "H", sparsity_flex: "H", area_kge: Some(619.0) },
        Table3Row { work: "A100 [17]", open_source: false, one_sided: true, two_sided: false, format_flex: "M", sparsity_flex: "L", area_kge: None },
        Table3Row { work: "ExTensor [12]", open_source: false, one_sided: false, two_sided: true, format_flex: "M", sparsity_flex: "H", area_kge: None },
        Table3Row { work: "SSSRs (ours)", open_source: true, one_sided: true, two_sided: true, format_flex: "H", sparsity_flex: "H", area_kge: Some(ours_area) },
    ]
}

// ======================================================================
// printing helpers
// ======================================================================

pub fn print_util_rows(title: &str, rows: &[UtilRow]) {
    println!("\n== {title} ==");
    println!("{:<8} {:>8} {:>10} {:>12}", "variant", "nnz", "FPU util", "w/o reduc.");
    for r in rows {
        let nr = r
            .utilization_nored
            .map(|u| format!("{u:.3}"))
            .unwrap_or_else(|| "-".into());
        println!("{:<8} {:>8} {:>10.3} {:>12}", r.variant, r.nnz, r.utilization, nr);
    }
}

pub fn print_speedup_rows(title: &str, rows: &[SpeedupRow]) {
    println!("\n== {title} ==");
    println!("{:<14} {:>8} {:<8} {:>8} {:>8}", "matrix", "n_nz/row", "variant", "speedup", "util");
    for r in rows {
        println!(
            "{:<14} {:>8.1} {:<8} {:>7.2}x {:>8.3}",
            r.matrix, r.avg_row_nnz, r.variant, r.speedup, r.utilization
        );
    }
}

pub fn print_density_rows(title: &str, rows: &[DensityRow]) {
    println!("\n== {title} ==");
    println!("{:>9} {:>9} {:>8}", "dens_a", "dens_b", "speedup");
    for r in rows {
        println!("{:>9.4} {:>9.4} {:>7.2}x", r.density_a, r.density_b, r.speedup);
    }
}

pub fn print_matsv_rows(title: &str, rows: &[MatSvRow]) {
    println!("\n== {title} ==");
    println!("{:<14} {:>8} {:>8} {:>8}", "matrix", "n_nz/row", "dens_v", "speedup");
    for r in rows {
        println!("{:<14} {:>8.1} {:>8.3} {:>7.2}x", r.matrix, r.avg_row_nnz, r.density, r.speedup);
    }
}

pub fn print_cluster_rows(title: &str, rows: &[ClusterRow]) {
    println!("\n== {title} ==");
    println!(
        "{:<14} {:>8} {:>8} {:>8} {:>9} {:>12} {:>12}",
        "matrix", "n_nz/row", "dens_v", "speedup", "FPU util", "base cyc", "sssr cyc"
    );
    for r in rows {
        println!(
            "{:<14} {:>8.1} {:>8.3} {:>7.2}x {:>9.3} {:>12} {:>12}",
            r.matrix, r.avg_row_nnz, r.density, r.speedup, r.utilization, r.base_cycles, r.sssr_cycles
        );
    }
}

pub fn print_sensitivity_rows(title: &str, xlabel: &str, rows: &[SensitivityRow]) {
    println!("\n== {title} ==");
    println!("{:>10} {:<8} {:>8}", xlabel, "kernel", "speedup");
    for r in rows {
        println!("{:>10.2} {:<8} {:>7.2}x", r.x, r.kernel, r.speedup);
    }
}

pub fn print_fig7() {
    println!("\n== Fig. 7b: streamer configurations ==");
    println!("{:<26} {:>10} {:>14}", "config", "area kGE", "min period ps");
    for r in fig7_configs() {
        println!("{:<26} {:>10.1} {:>14.0}", r.config, r.area_kge, r.min_period_ps);
    }
    println!("\n== Fig. 7c: area vs clock target (default streamer) ==");
    println!("{:>10} {:>10}", "target ps", "area kGE");
    for r in fig7_area_vs_period() {
        println!("{:>10.0} {:>10.1}", r.target_ps, r.area_kge);
    }
    let oh = crate::model::area::cluster_overhead_fraction(8);
    println!("\ncluster area overhead (8 cores): {:.2} %", oh * 100.0);
}

pub fn print_energy_rows(title: &str, rows: &[EnergyRow]) {
    println!("\n== {title} ==");
    println!(
        "{:<14} {:<6} {:>10} {:>10} {:>10}",
        "matrix", "var", "pJ/op", "power mW", "total uJ"
    );
    for r in rows {
        println!(
            "{:<14} {:<6} {:>10.1} {:>10.1} {:>10.2}",
            r.matrix, r.variant, r.pj_per_op, r.power_mw, r.total_uj
        );
    }
}

pub fn print_table2(ours: f64) {
    println!("\n== Table 2: FP64 sMxdV peak FP utilization ==");
    println!("{:<22} {:<16} {:<14} {:>10}", "work", "platform", "format", "peak util");
    for (work, platform, format, util) in TABLE2_LITERATURE {
        println!("{:<22} {:<16} {:<14} {:>9.2}%", work, platform, format, util * 100.0);
    }
    println!(
        "{:<22} {:<16} {:<14} {:>9.2}%",
        "SSSRs (ours, sim)", "Snitch + SSSRs", "CSR", ours * 100.0
    );
    let best_cpu = 0.047;
    let best_gpu = 0.27;
    println!(
        "-> vs best CPU {:.1}x, vs best GPU {:.1}x",
        ours / best_cpu,
        ours / best_gpu
    );
}

pub fn print_table3() {
    println!("\n== Table 3: hardware designs ==");
    println!(
        "{:<20} {:>5} {:>9} {:>9} {:>7} {:>9} {:>9}",
        "work", "open", "1-sided", "2-sided", "fmt", "sparsity", "kGE"
    );
    for r in table3() {
        println!(
            "{:<20} {:>5} {:>9} {:>9} {:>7} {:>9} {:>9}",
            r.work,
            if r.open_source { "yes" } else { "no" },
            if r.one_sided { "yes" } else { "no" },
            if r.two_sided { "yes" } else { "no" },
            r.format_flex,
            r.sparsity_flex,
            r.area_kge.map(|a| format!("{a:.0}")).unwrap_or_else(|| "-".into()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_literature_data_hygiene() {
        assert_eq!(TABLE2_LITERATURE.len(), 10);
        assert!(TABLE2_LITERATURE.iter().all(|(_, _, _, u)| *u > 0.0 && *u < 1.0));
    }

    #[test]
    fn fig7_rows_cover_configs() {
        let rows = fig7_configs();
        assert_eq!(rows.len(), 6);
        assert!(rows[0].area_kge < rows.last().unwrap().area_kge);
    }

    #[test]
    fn repeated_fiber_allows_duplicates() {
        let f = repeated_idx_fiber(1, 256, 1000);
        assert_eq!(f.nnz(), 1000);
        assert!(f.idcs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn table3_has_ours_with_modeled_area() {
        let rows = table3();
        let ours = rows.last().unwrap();
        assert_eq!(ours.work, "SSSRs (ours)");
        assert!(ours.one_sided && ours.two_sided && ours.open_source);
        assert!((29.0..31.0).contains(&ours.area_kge.unwrap()));
    }
}
