//! Benchmark harness: every table and figure of the paper's evaluation
//! (§4–§5) expressed as a declarative [`ExperimentSpec`] over the
//! [`crate::experiments`] engine. The bench targets under `rust/benches/`
//! and the `repro` CLI both obtain specs here, execute them through the
//! parallel [`crate::experiments::Runner`], and render the resulting
//! unified [`Record`]s as tables and/or `BENCH_<fig>.json` files.
//!
//! Sweep sizes: the default ("quick") configuration subsamples the
//! corpus and caps matrix sizes so `cargo bench` completes in minutes;
//! set `REPRO_FULL=1` for the full corpus (including mycielskian12's
//! 407 k stored nonzeros). Every grid point seeds its own workload
//! generators, so results are independent of `--jobs`.

use crate::coordinator::run_cluster_smxdv;
use crate::experiments::{grid2, ColFmt, Column, ExperimentSpec, Point, Record};
use crate::formats::SpVec;
use crate::kernels::api::{must_execute, Detail, ExecCfg, KernelRun, Operand};
use crate::kernels::apps::Stencil1d;
use crate::kernels::driver::{run_smxdv, run_svxsv};
use crate::kernels::{IdxWidth, Report, Variant};
use crate::matgen;
use crate::pipeline::{self, PipeCfg};
use crate::model::energy::EnergyModel;
use crate::model::{streamer_area, streamer_min_period_ps, SlotKind, StreamerCfg};
use crate::serve::{self, Policy, Scenario, ServeCfg, SloCfg, StreamCfg};
use crate::sim::{ClusterCfg, SystemCfg};

pub fn full_mode() -> bool {
    std::env::var("REPRO_FULL").map(|v| v == "1").unwrap_or(false)
}

fn corpus_selection() -> Vec<matgen::CorpusEntry> {
    let all = matgen::corpus();
    if full_mode() {
        all
    } else {
        // quick: subsample across the n̄_nz range, cap nnz for wall time
        all.into_iter()
            .filter(|e| e.matrix.nnz() <= 140_000)
            .enumerate()
            .filter(|(i, _)| i % 2 == 0 || *i < 4)
            .map(|(_, e)| e)
            .collect()
    }
}

fn nnz_sweep() -> Vec<usize> {
    if full_mode() {
        vec![4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096]
    } else {
        vec![4, 16, 64, 256, 1024, 4096]
    }
}

fn density_sweep() -> Vec<f64> {
    if full_mode() {
        vec![0.0003, 0.001, 0.003, 0.01, 0.03, 0.1, 0.3]
    } else {
        vec![0.001, 0.01, 0.1, 0.3]
    }
}

/// A fiber with *repeated* 8-bit indices (the `sssr8r` series: "8-bit
/// indirection with repeated indices", §4.1.1).
fn repeated_idx_fiber(seed: u64, dim: usize, nnz: usize) -> SpVec {
    let mut r = crate::util::Pcg::new(seed);
    let mut idcs: Vec<u32> = (0..nnz).map(|_| r.below(dim as u64) as u32).collect();
    idcs.sort_unstable();
    let vals = (0..nnz).map(|_| r.normal()).collect();
    SpVec { dim, idcs, vals }
}

/// The paper uses its peak-speedup matrix mycielskian12 here; quick mode
/// uses mycielskian11 (same construction, quarter size).
fn fig6_matrix() -> crate::formats::Csr {
    if full_mode() {
        matgen::mycielskian(12)
    } else {
        matgen::mycielskian(11)
    }
}

// ======================================================================
// column layouts
// ======================================================================

fn util_columns() -> Vec<Column> {
    vec![
        Column::new("variant", "variant", 8, ColFmt::Str),
        Column::new("nnz", "nnz", 8, ColFmt::Int),
        Column::new("utilization", "FPU util", 10, ColFmt::Fixed(3)),
        Column::new("utilization_nored", "w/o reduc.", 12, ColFmt::Fixed(3)),
    ]
}

fn speedup_columns() -> Vec<Column> {
    vec![
        Column::new("matrix", "matrix", 14, ColFmt::Str),
        Column::new("avg_row_nnz", "n_nz/row", 8, ColFmt::Fixed(1)),
        Column::new("variant", "variant", 8, ColFmt::Str),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
        Column::new("utilization", "util", 8, ColFmt::Fixed(3)),
    ]
}

fn density_columns() -> Vec<Column> {
    vec![
        Column::new("density_a", "dens_a", 9, ColFmt::Fixed(4)),
        Column::new("density_b", "dens_b", 9, ColFmt::Fixed(4)),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
    ]
}

fn matsv_columns() -> Vec<Column> {
    vec![
        Column::new("matrix", "matrix", 14, ColFmt::Str),
        Column::new("avg_row_nnz", "n_nz/row", 8, ColFmt::Fixed(1)),
        Column::new("density", "dens_v", 8, ColFmt::Fixed(3)),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
    ]
}

fn cluster_columns() -> Vec<Column> {
    vec![
        Column::new("matrix", "matrix", 14, ColFmt::Str),
        Column::new("avg_row_nnz", "n_nz/row", 8, ColFmt::Fixed(1)),
        Column::new("density", "dens_v", 8, ColFmt::Fixed(3)),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
        Column::new("utilization", "FPU util", 9, ColFmt::Fixed(3)),
        Column::new("base_cycles", "base cyc", 12, ColFmt::Int),
        Column::new("sssr_cycles", "sssr cyc", 12, ColFmt::Int),
    ]
}

fn sensitivity_columns(xlabel: &'static str) -> Vec<Column> {
    vec![
        Column::new("x", xlabel, 10, ColFmt::Fixed(2)),
        Column::new("kernel", "kernel", 8, ColFmt::Str),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
    ]
}

fn energy_columns() -> Vec<Column> {
    vec![
        Column::new("matrix", "matrix", 14, ColFmt::Str),
        Column::new("variant", "var", 6, ColFmt::Str),
        Column::new("pj_per_op", "pJ/op", 10, ColFmt::Fixed(1)),
        Column::new("power_mw", "power mW", 10, ColFmt::Fixed(1)),
        Column::new("total_uj", "total uJ", 10, ColFmt::Fixed(2)),
    ]
}

// ======================================================================
// Fig. 4a/4b — single-CC sV×dV / sV+dV FPU utilization vs nonzeros
// ======================================================================

pub fn spec_fig4a() -> ExperimentSpec {
    let points = nnz_sweep().into_iter().map(|n| Point::default().nnz(n)).collect();
    let dim16 = 8192; // dense operand resident in the TCDM
    let dim8 = 256;
    // shared across grid points; immutable, so safe under parallel workers
    let b16 = matgen::random_dense(101, dim16);
    let b8 = matgen::random_dense(102, dim8);
    ExperimentSpec {
        name: "fig4a",
        title: "Fig. 4a: CC sVxdV FPU utilization vs nonzeros".into(),
        columns: util_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let nnz = p.nnz.unwrap();
            let rec = |variant: &str, utilization: f64, nored: Option<f64>| {
                Record::new("fig4a")
                    .str("variant", variant)
                    .int("nnz", nnz as i64)
                    .num("utilization", utilization)
                    .opt_num("utilization_nored", nored)
            };
            let svxdv = |v: Variant, iw: IdxWidth, a: &SpVec, b: &[f64], skip: bool| -> Report {
                let mut cfg = ExecCfg::single_cc();
                if skip {
                    cfg = cfg.skip_reduction();
                }
                must_execute("svxdv", v, iw, &[Operand::SpVec(a), Operand::Dense(b)], &cfg).report
            };
            let mut out = vec![];
            let a16 = matgen::random_spvec(200 + nnz as u64, dim16, nnz);
            // BASE and SSR perform identically for all index sizes (§4.1.1)
            let r = svxdv(Variant::Base, IdxWidth::U16, &a16, &b16, false);
            out.push(rec("base", r.utilization, None));
            let r = svxdv(Variant::Ssr, IdxWidth::U16, &a16, &b16, false);
            out.push(rec("ssr", r.utilization, None));
            for (name, iw) in [("sssr16", IdxWidth::U16), ("sssr32", IdxWidth::U32)] {
                let with = svxdv(Variant::Sssr, iw, &a16, &b16, false);
                let wo = svxdv(Variant::Sssr, iw, &a16, &b16, true);
                out.push(rec(name, with.utilization, Some(wo.utilization)));
            }
            if nnz <= dim8 {
                let a8 = matgen::random_spvec(300 + nnz as u64, dim8, nnz);
                let with = svxdv(Variant::Sssr, IdxWidth::U8, &a8, &b8, false);
                let wo = svxdv(Variant::Sssr, IdxWidth::U8, &a8, &b8, true);
                out.push(rec("sssr8", with.utilization, Some(wo.utilization)));
            }
            // repeated 8-bit indices scale past 256 nonzeros
            let a8r = repeated_idx_fiber(400 + nnz as u64, dim8, nnz);
            let with = svxdv(Variant::Sssr, IdxWidth::U8, &a8r, &b8, false);
            let wo = svxdv(Variant::Sssr, IdxWidth::U8, &a8r, &b8, true);
            out.push(rec("sssr8r", with.utilization, Some(wo.utilization)));
            out
        }),
    }
}

pub fn spec_fig4b() -> ExperimentSpec {
    let points = nnz_sweep().into_iter().map(|n| Point::default().nnz(n)).collect();
    let dim16 = 8192;
    let dim8 = 256;
    let b16 = matgen::random_dense(111, dim16);
    let b8 = matgen::random_dense(112, dim8);
    ExperimentSpec {
        name: "fig4b",
        title: "Fig. 4b: CC sV+dV FPU utilization vs nonzeros".into(),
        columns: util_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let nnz = p.nnz.unwrap();
            let mut out = vec![];
            let a16 = matgen::random_spvec(500 + nnz as u64, dim16, nnz);
            let ops = [Operand::SpVec(&a16), Operand::Dense(&b16)];
            for (name, v, iw) in [
                ("base", Variant::Base, IdxWidth::U16),
                ("ssr", Variant::Ssr, IdxWidth::U16),
                ("sssr16", Variant::Sssr, IdxWidth::U16),
                ("sssr32", Variant::Sssr, IdxWidth::U32),
            ] {
                let r = must_execute("svpdv", v, iw, &ops, &ExecCfg::single_cc()).report;
                out.push(
                    Record::new("fig4b")
                        .str("variant", name)
                        .int("nnz", nnz as i64)
                        .num("utilization", r.utilization),
                );
            }
            // timing-only (ExecCfg::unchecked): repeated indices make
            // the in-place update order-dependent
            let a8r = repeated_idx_fiber(600 + nnz as u64, dim8, nnz);
            let ops = [Operand::SpVec(&a8r), Operand::Dense(&b8)];
            let r = must_execute(
                "svpdv",
                Variant::Sssr,
                IdxWidth::U8,
                &ops,
                &ExecCfg::single_cc().unchecked(),
            )
            .report;
            out.push(
                Record::new("fig4b")
                    .str("variant", "sssr8r")
                    .int("nnz", nnz as i64)
                    .num("utilization", r.utilization),
            );
            out
        }),
    }
}

// ======================================================================
// Fig. 4c — single-CC sM×dV speedups over BASE per matrix
// ======================================================================

pub fn spec_fig4c() -> ExperimentSpec {
    let corpus = corpus_selection();
    let points = corpus
        .iter()
        .enumerate()
        .map(|(i, e)| Point::at(i).label(e.name))
        .collect();
    ExperimentSpec {
        name: "fig4c",
        title: "Fig. 4c: CC sMxdV speedups over BASE".into(),
        columns: speedup_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let e = &corpus[p.idx.unwrap()];
            let b = matgen::random_dense(700, e.matrix.ncols);
            let ops = [Operand::Csr(&e.matrix), Operand::Dense(&b)];
            let cfg = ExecCfg::single_cc();
            let base = must_execute("smxdv", Variant::Base, IdxWidth::U16, &ops, &cfg).report;
            let mut out = vec![];
            for (name, v, iw) in [
                ("ssr", Variant::Ssr, IdxWidth::U16),
                ("sssr16", Variant::Sssr, IdxWidth::U16),
                ("sssr32", Variant::Sssr, IdxWidth::U32),
            ] {
                let r = must_execute("smxdv", v, iw, &ops, &cfg).report;
                out.push(
                    Record::new("fig4c")
                        .str("matrix", e.name)
                        .num("avg_row_nnz", e.matrix.avg_row_nnz())
                        .str("variant", name)
                        .num("speedup", base.cycles as f64 / r.cycles as f64)
                        .num("utilization", r.utilization),
                );
            }
            out
        }),
    }
}

// ======================================================================
// Fig. 4d/4e — single-CC sV×sV / sV+sV speedups vs operand densities
// ======================================================================

/// Shared spec for the sparse-sparse vector kernels, parameterized by
/// registry kernel name (`"svxsv"` / `"svpsv"`). The paper uses dense
/// size 60k; quick mode uses 20k (same density semantics, smaller wall
/// time).
fn spec_svv(name: &'static str, title: &str, which: &'static str) -> ExperimentSpec {
    let dim = if full_mode() { 60_000 } else { 20_000 };
    let ds = density_sweep();
    let points = grid2(&ds, &ds)
        .into_iter()
        .map(|(da, db)| Point::default().densities(da, db))
        .collect();
    ExperimentSpec {
        name,
        title: title.into(),
        columns: density_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let (da, db) = (p.density_a.unwrap(), p.density_b.unwrap());
            let na = ((da * dim as f64) as usize).max(1);
            let nb = ((db * dim as f64) as usize).max(1);
            let a = matgen::random_spvec(800 + na as u64, dim, na);
            let b = matgen::random_spvec(900 + nb as u64, dim, nb);
            let ops = [Operand::SpVec(&a), Operand::SpVec(&b)];
            let cfg = ExecCfg::single_cc();
            let base = must_execute(which, Variant::Base, IdxWidth::U32, &ops, &cfg).report;
            let sssr = must_execute(which, Variant::Sssr, IdxWidth::U32, &ops, &cfg).report;
            vec![Record::new(name)
                .num("density_a", da)
                .num("density_b", db)
                .num("speedup", base.cycles as f64 / sssr.cycles as f64)]
        }),
    }
}

pub fn spec_fig4d() -> ExperimentSpec {
    spec_svv("fig4d", "Fig. 4d: CC sVxsV speedup vs densities", "svxsv")
}

pub fn spec_fig4e() -> ExperimentSpec {
    spec_svv("fig4e", "Fig. 4e: CC sV+sV speedup vs densities", "svpsv")
}

// ======================================================================
// Fig. 4f — single-CC sM×sV speedups per matrix and vector density
// ======================================================================

pub fn spec_fig4f() -> ExperimentSpec {
    let corpus = corpus_selection();
    let densities = if full_mode() { vec![0.001, 0.01, 0.1, 0.3] } else { vec![0.01, 0.3] };
    let mut points = vec![];
    for (i, e) in corpus.iter().enumerate() {
        for &dv in &densities {
            points.push(Point::at(i).label(e.name).density(dv));
        }
    }
    ExperimentSpec {
        name: "fig4f",
        title: "Fig. 4f: CC sMxsV speedups over BASE".into(),
        columns: matsv_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let e = &corpus[p.idx.unwrap()];
            let dv = p.density_a.unwrap();
            let nnz = ((dv * e.matrix.ncols as f64) as usize).max(1);
            let b = matgen::random_spvec(1000 + nnz as u64, e.matrix.ncols, nnz);
            let ops = [Operand::Csr(&e.matrix), Operand::SpVec(&b)];
            let cfg = ExecCfg::single_cc();
            let base = must_execute("smxsv", Variant::Base, IdxWidth::U16, &ops, &cfg).report;
            let sssr = must_execute("smxsv", Variant::Sssr, IdxWidth::U16, &ops, &cfg).report;
            vec![Record::new("fig4f")
                .str("matrix", e.name)
                .num("avg_row_nnz", e.matrix.avg_row_nnz())
                .num("density", dv)
                .num("speedup", base.cycles as f64 / sssr.cycles as f64)]
        }),
    }
}

// ======================================================================
// Fig. 5a/5b — eight-core cluster speedups (HBM + interconnect models)
// ======================================================================

fn cluster_record(
    experiment: &str,
    name: &str,
    avg_row_nnz: f64,
    density: f64,
    base: &Report,
    sssr: &Report,
    cores: usize,
) -> Record {
    Record::new(experiment)
        .str("matrix", name)
        .num("avg_row_nnz", avg_row_nnz)
        .num("density", density)
        .num("speedup", base.cycles as f64 / sssr.cycles as f64)
        .num(
            "utilization",
            sssr.payload as f64 / (sssr.cycles as f64 * cores as f64),
        )
        .int("base_cycles", base.cycles as i64)
        .int("sssr_cycles", sssr.cycles as i64)
}

pub fn spec_fig5a() -> ExperimentSpec {
    let corpus = corpus_selection();
    let points = corpus
        .iter()
        .enumerate()
        .map(|(i, e)| Point::at(i).label(e.name))
        .collect();
    ExperimentSpec {
        name: "fig5a",
        title: "Fig. 5a: cluster sMxdV speedups (16-bit)".into(),
        columns: cluster_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cfg = ClusterCfg::paper_cluster();
            let e = &corpus[p.idx.unwrap()];
            let b = matgen::random_dense(1100, e.matrix.ncols);
            let ops = [Operand::Csr(&e.matrix), Operand::Dense(&b)];
            let ec = ExecCfg::cluster(cfg.clone());
            let base = must_execute("smxdv", Variant::Base, IdxWidth::U16, &ops, &ec).report;
            let sssr = must_execute("smxdv", Variant::Sssr, IdxWidth::U16, &ops, &ec).report;
            vec![cluster_record(
                "fig5a",
                e.name,
                e.matrix.avg_row_nnz(),
                1.0,
                &base,
                &sssr,
                cfg.cores,
            )]
        }),
    }
}

pub fn spec_fig5b() -> ExperimentSpec {
    let corpus = corpus_selection();
    let densities = if full_mode() { vec![0.001, 0.01, 0.1, 0.3] } else { vec![0.01, 0.3] };
    let mut points = vec![];
    for (i, e) in corpus.iter().enumerate() {
        for &dv in &densities {
            points.push(Point::at(i).label(e.name).density(dv));
        }
    }
    ExperimentSpec {
        name: "fig5b",
        title: "Fig. 5b: cluster sMxsV speedups (16-bit)".into(),
        columns: cluster_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cfg = ClusterCfg::paper_cluster();
            let e = &corpus[p.idx.unwrap()];
            let dv = p.density_a.unwrap();
            let nnz = ((dv * e.matrix.ncols as f64) as usize).max(1);
            let b = matgen::random_spvec(1200 + nnz as u64, e.matrix.ncols, nnz);
            let ops = [Operand::Csr(&e.matrix), Operand::SpVec(&b)];
            let ec = ExecCfg::cluster(cfg.clone());
            let base = must_execute("smxsv", Variant::Base, IdxWidth::U16, &ops, &ec).report;
            let sssr = must_execute("smxsv", Variant::Sssr, IdxWidth::U16, &ops, &ec).report;
            vec![cluster_record(
                "fig5b",
                e.name,
                e.matrix.avg_row_nnz(),
                dv,
                &base,
                &sssr,
                cfg.cores,
            )]
        }),
    }
}

// ======================================================================
// Fig. 6 — bandwidth / latency sensitivity
// ======================================================================

/// Shared shape of Fig. 6a/6b: sweep one cluster parameter on the
/// Mycielskian peak matrix, measure smxdv and smxsv speedups per point.
fn spec_fig6(
    name: &'static str,
    title: &str,
    xlabel: &'static str,
    xs: Vec<f64>,
    cfg_of: impl Fn(f64) -> ClusterCfg + Send + Sync + 'static,
    seed_dense: u64,
    seed_spvec: u64,
) -> ExperimentSpec {
    let points = xs.into_iter().map(|x| Point::default().x(x)).collect();
    // one matrix + operand pair for the whole sweep (fig6_matrix is the
    // largest corpus member; don't rebuild it per grid point)
    let m = fig6_matrix();
    let b = matgen::random_dense(seed_dense, m.ncols);
    let dv = 0.01;
    let sv = matgen::random_spvec(seed_spvec, m.ncols, ((dv * m.ncols as f64) as usize).max(1));
    ExperimentSpec {
        name,
        title: title.into(),
        columns: sensitivity_columns(xlabel),
        points,
        measure: Box::new(move |p: &Point| {
            let x = p.x.unwrap();
            let ec = ExecCfg::cluster(cfg_of(x));
            let mut out = vec![];
            let ops = [Operand::Csr(&m), Operand::Dense(&b)];
            let base = must_execute("smxdv", Variant::Base, IdxWidth::U16, &ops, &ec).report;
            let sssr = must_execute("smxdv", Variant::Sssr, IdxWidth::U16, &ops, &ec).report;
            out.push(
                Record::new(name)
                    .num("x", x)
                    .str("kernel", "smxdv")
                    .num("speedup", base.cycles as f64 / sssr.cycles as f64),
            );
            let ops = [Operand::Csr(&m), Operand::SpVec(&sv)];
            let base = must_execute("smxsv", Variant::Base, IdxWidth::U16, &ops, &ec).report;
            let sssr = must_execute("smxsv", Variant::Sssr, IdxWidth::U16, &ops, &ec).report;
            out.push(
                Record::new(name)
                    .num("x", x)
                    .str("kernel", "smxsv")
                    .num("speedup", base.cycles as f64 / sssr.cycles as f64),
            );
            out
        }),
    }
}

pub fn spec_fig6a() -> ExperimentSpec {
    let bws = if full_mode() {
        vec![3.6, 2.4, 1.6, 1.2, 0.8, 0.6, 0.4]
    } else {
        vec![3.6, 1.6, 0.8, 0.4]
    };
    spec_fig6(
        "fig6a",
        "Fig. 6a: speedup vs DRAM channel bandwidth",
        "Gb/s/pin",
        bws,
        |bw| ClusterCfg { dram_gbps_pin: bw, ..ClusterCfg::paper_cluster() },
        1300,
        1301,
    )
}

pub fn spec_fig6b() -> ExperimentSpec {
    let lats: Vec<f64> = if full_mode() {
        vec![0.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0]
    } else {
        vec![0.0, 16.0, 64.0, 256.0]
    };
    spec_fig6(
        "fig6b",
        "Fig. 6b: speedup vs on-chip interconnect latency",
        "cycles",
        lats,
        |lat| ClusterCfg { ic_latency: lat as u64, ..ClusterCfg::paper_cluster() },
        1400,
        1401,
    )
}

// ======================================================================
// scale — multi-cluster scaling on shared HBM channels (system layer)
// ======================================================================

/// Cluster counts swept by every `spec_scale_*` experiment.
pub const SCALE_CLUSTERS: [usize; 4] = [1, 2, 4, 8];

fn scale_channel_counts() -> Vec<usize> {
    if full_mode() {
        vec![1, 2, 4, 8]
    } else {
        vec![1, 2]
    }
}

/// Matrices for the scaling sweeps: bandwidth-hungry corpus members
/// with enough rows to shard eight ways.
fn scale_corpus() -> Vec<matgen::CorpusEntry> {
    let mut v = vec![
        matgen::CorpusEntry {
            name: "rand2k_64k",
            matrix: matgen::random_csr(16, 2048, 2048, 65536),
        },
        matgen::CorpusEntry { name: "mycielskian11", matrix: matgen::mycielskian(11) },
    ];
    if full_mode() {
        v.push(matgen::CorpusEntry {
            name: "rand2k_128k",
            matrix: matgen::random_csr(17, 2048, 2048, 131072),
        });
        v.push(matgen::CorpusEntry { name: "mycielskian12", matrix: matgen::mycielskian(12) });
    }
    v
}

fn scale_columns() -> Vec<Column> {
    vec![
        Column::new("matrix", "matrix", 14, ColFmt::Str),
        Column::new("channels", "chan", 5, ColFmt::Int),
        Column::new("clusters", "clus", 5, ColFmt::Int),
        Column::new("cycles", "cycles", 12, ColFmt::Int),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
        Column::new("efficiency", "par eff", 8, ColFmt::Fixed(2)),
        Column::new("queue_cycles", "hbm queue", 12, ColFmt::Int),
        Column::new("skew_cycles", "skew", 9, ColFmt::Int),
    ]
}

fn scale_record(
    name: &'static str,
    matrix: &str,
    channels: usize,
    clusters: usize,
    base_cycles: u64,
    run: &KernelRun,
) -> Record {
    let (queue_cycles, skew_cycles) = match &run.detail {
        Detail::System { shards, reduction } => (
            shards.iter().map(|s| s.hbm.queue_cycles).sum::<u64>(),
            reduction.skew_cycles,
        ),
        _ => unreachable!("scale sweeps run on the system target"),
    };
    let speedup = base_cycles as f64 / run.report.cycles as f64;
    let utilization = run.report.per_core_utilization();
    Record::new(name)
        .str("matrix", matrix)
        .int("channels", channels as i64)
        .int("clusters", clusters as i64)
        .int("cycles", run.report.cycles as i64)
        .num("speedup", speedup)
        .num("efficiency", speedup / clusters as f64)
        .int("queue_cycles", queue_cycles as i64)
        .int("skew_cycles", skew_cycles as i64)
        .int("hbm_bytes", run.report.stats.dram_bytes as i64)
        .num("utilization", utilization)
}

/// Shared shape of the `scale`/`scale_sv` sweeps: one grid point per
/// (matrix, channel count); each point runs the SSSR kernel at every
/// cluster count and reports speedups against the matrix's 1-cluster
/// run. That baseline is channel-count-invariant (a single cluster
/// always maps to channel 0) and the most expensive run of the sweep,
/// so it is simulated once per matrix and shared across that matrix's
/// channel points through a `OnceLock` — value-deterministic, so the
/// records stay byte-identical for every `--jobs`.
fn spec_scale_kernel(name: &'static str, title: String, smxsv: bool) -> ExperimentSpec {
    let corpus = scale_corpus();
    let mut points = vec![];
    for (i, e) in corpus.iter().enumerate() {
        for &ch in &scale_channel_counts() {
            points.push(Point::at(i).label(e.name).x(ch as f64));
        }
    }
    let baselines: Vec<std::sync::OnceLock<KernelRun>> =
        corpus.iter().map(|_| std::sync::OnceLock::new()).collect();
    ExperimentSpec {
        name,
        title,
        columns: scale_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let i = p.idx.unwrap();
            let e = &corpus[i];
            let channels = p.x.unwrap() as usize;
            let dense;
            let fiber;
            if smxsv {
                let nnz = ((0.01 * e.matrix.ncols as f64) as usize).max(1);
                fiber = Some(matgen::random_spvec(1800 + nnz as u64, e.matrix.ncols, nnz));
                dense = None;
            } else {
                dense = Some(matgen::random_dense(1700, e.matrix.ncols));
                fiber = None;
            }
            let run_at = |clusters: usize, channels: usize| -> KernelRun {
                let ec = ExecCfg::system(SystemCfg::paper_system(clusters, channels));
                match (&dense, &fiber) {
                    (Some(b), _) => {
                        let ops = [Operand::Csr(&e.matrix), Operand::Dense(b)];
                        must_execute("smxdv", Variant::Sssr, IdxWidth::U16, &ops, &ec)
                    }
                    (_, Some(v)) => {
                        let ops = [Operand::Csr(&e.matrix), Operand::SpVec(v)];
                        must_execute("smxsv", Variant::Sssr, IdxWidth::U16, &ops, &ec)
                    }
                    _ => unreachable!(),
                }
            };
            let base = baselines[i].get_or_init(|| run_at(1, 1));
            let mut out = vec![scale_record(name, e.name, channels, 1, base.report.cycles, base)];
            for &clusters in &SCALE_CLUSTERS[1..] {
                let run = run_at(clusters, channels);
                let rec = scale_record(name, e.name, channels, clusters, base.report.cycles, &run);
                out.push(rec);
            }
            out
        }),
    }
}

/// `scale`: multi-cluster SSSR SpMV (sM×dV) cycle counts and speedups
/// over clusters × channels × matrices — the system layer's headline
/// sweep (`repro sweep scale` → `BENCH_scale.json`).
pub fn spec_scale() -> ExperimentSpec {
    spec_scale_kernel(
        "scale",
        "scale: multi-cluster SpMV on shared HBM channels".into(),
        false,
    )
}

/// `scale_sv`: the SpMSpV (sM×sV) companion sweep.
pub fn spec_scale_sv() -> ExperimentSpec {
    spec_scale_kernel(
        "scale_sv",
        "scale_sv: multi-cluster SpMSpV on shared HBM channels (d_v=1%)".into(),
        true,
    )
}

// ======================================================================
// graph — CSF SpGEMM and pattern matching on the corpus graphs
// ======================================================================

/// Graphs for the `graph` sweep: exact Mycielskian constructions (the
/// corpus' triangle-free family) plus symmetrized R-MAT power-law
/// graphs. Quick mode keeps the sweep in seconds; `REPRO_FULL=1` scales
/// to the corpus-sized instances.
fn graph_corpus() -> Vec<matgen::CorpusEntry> {
    if full_mode() {
        vec![
            matgen::CorpusEntry { name: "mycielskian8", matrix: matgen::mycielskian(8) },
            matgen::CorpusEntry { name: "mycielskian9", matrix: matgen::mycielskian(9) },
            matgen::CorpusEntry { name: "rmat9u_4", matrix: matgen::undirected_graph(21, 9, 4) },
            matgen::CorpusEntry { name: "rmat10u_8", matrix: matgen::undirected_graph(22, 10, 8) },
        ]
    } else {
        vec![
            matgen::CorpusEntry { name: "mycielskian7", matrix: matgen::mycielskian(7) },
            matgen::CorpusEntry { name: "mycielskian8", matrix: matgen::mycielskian(8) },
            matgen::CorpusEntry { name: "rmat7u_4", matrix: matgen::undirected_graph(21, 7, 4) },
            matgen::CorpusEntry { name: "rmat8u_8", matrix: matgen::undirected_graph(22, 8, 8) },
        ]
    }
}

fn graph_columns() -> Vec<Column> {
    vec![
        Column::new("graph", "graph", 14, ColFmt::Str),
        Column::new("nodes", "nodes", 6, ColFmt::Int),
        Column::new("edges", "edges", 8, ColFmt::Int),
        Column::new("kernel", "kernel", 10, ColFmt::Str),
        Column::new("base_cycles", "base cyc", 12, ColFmt::Int),
        Column::new("sssr_cycles", "sssr cyc", 12, ColFmt::Int),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
        Column::new("payload", "payload", 10, ColFmt::Int),
    ]
}

/// `graph`: SSSR-vs-BASE cycle counts of the CSF tensor and graph
/// kernels — triangle counting (`tricnt`, streamed intersections) and
/// adjacency squaring (`smxsm_csf`, streamed unions) — over the graph
/// corpus (`repro sweep graph` → `BENCH_graph.json`).
pub fn spec_graph() -> ExperimentSpec {
    let corpus = graph_corpus();
    let points = corpus
        .iter()
        .enumerate()
        .map(|(i, e)| Point::at(i).label(e.name))
        .collect();
    ExperimentSpec {
        name: "graph",
        title: "graph: CSF SpGEMM + triangle counting, SSSR vs BASE".into(),
        columns: graph_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let e = &corpus[p.idx.unwrap()];
            let g = &e.matrix;
            // the BASE SpGEMM merges grow with flops, not nnz: give the
            // full-mode graphs headroom over the default hang guard
            let cfg = ExecCfg::single_cc().with_limit(2_000_000_000);
            let rec = |kernel: &str, base: &Report, sssr: &Report, extra: Option<(&str, f64)>| {
                let mut r = Record::new("graph")
                    .str("graph", e.name)
                    .int("nodes", g.nrows as i64)
                    .int("edges", (g.nnz() / 2) as i64)
                    .str("kernel", kernel)
                    .int("base_cycles", base.cycles as i64)
                    .int("sssr_cycles", sssr.cycles as i64)
                    .num("speedup", base.cycles as f64 / sssr.cycles as f64)
                    .int("payload", sssr.payload as i64);
                if let Some((k, v)) = extra {
                    r = r.num(k, v);
                }
                r
            };
            // triangle counting on the adjacency pattern
            let tri_ops = [Operand::Csr(g)];
            let base = must_execute("tricnt", Variant::Base, IdxWidth::U16, &tri_ops, &cfg);
            let sssr = must_execute("tricnt", Variant::Sssr, IdxWidth::U16, &tri_ops, &cfg);
            let triangles = sssr.output.as_scalar().unwrap();
            let mut out = vec![rec(
                "tricnt",
                &base.report,
                &sssr.report,
                Some(("triangles", triangles)),
            )];
            // CSF SpGEMM: square the adjacency (paths of length two)
            let t = crate::formats::Csf::from_csr(g);
            let csf_ops = [Operand::Csf(&t), Operand::Csf(&t)];
            let base = must_execute("smxsm_csf", Variant::Base, IdxWidth::U16, &csf_ops, &cfg);
            let sssr = must_execute("smxsm_csf", Variant::Sssr, IdxWidth::U16, &csf_ops, &cfg);
            out.push(rec("smxsm_csf", &base.report, &sssr.report, None));
            out
        }),
    }
}

// ======================================================================
// spgemm — two-phase SpGEMM at system scale (symbolic/numeric split)
// ======================================================================

/// Cluster configuration of the `spgemm` sweep: the Table-1 cluster
/// with the TCDM widened so one cluster can hold its exactly-sized
/// output shard of the squared adjacency (the symbolic pass guarantees
/// no over-allocation; the quick graphs fit in 8 MiB with headroom).
fn spgemm_cluster() -> ClusterCfg {
    ClusterCfg { tcdm_bytes: 8 << 20, ..ClusterCfg::paper_cluster() }
}

fn spgemm_columns() -> Vec<Column> {
    vec![
        Column::new("graph", "graph", 14, ColFmt::Str),
        Column::new("clusters", "clus", 5, ColFmt::Int),
        Column::new("base_cycles", "base cyc", 12, ColFmt::Int),
        Column::new("sssr_cycles", "sssr cyc", 12, ColFmt::Int),
        Column::new("speedup", "speedup", 8, ColFmt::FixedX(2)),
        Column::new("scaling", "vs 1clus", 8, ColFmt::FixedX(2)),
        Column::new("efficiency", "par eff", 8, ColFmt::Fixed(2)),
        Column::new("skew_cycles", "skew", 9, ColFmt::Int),
    ]
}

/// `spgemm`: two-phase (symbolic/numeric) CSF SpGEMM squaring the graph
/// corpus' adjacencies on the system target — SSSR vs BASE at every
/// [`SCALE_CLUSTERS`] count (`repro sweep spgemm` → `BENCH_spgemm.json`).
/// Every run goes through the registry's verified execute path, so each
/// grid point also re-checks the N-cluster result against the host
/// oracle. The 1-cluster SSSR baseline of the `scaling` column is shared
/// per matrix through a `OnceLock` (value-deterministic under `--jobs`).
pub fn spec_spgemm() -> ExperimentSpec {
    let corpus = graph_corpus();
    let mut points = vec![];
    for (i, e) in corpus.iter().enumerate() {
        for &k in &SCALE_CLUSTERS {
            points.push(Point::at(i).label(e.name).x(k as f64));
        }
    }
    let baselines: Vec<std::sync::OnceLock<u64>> =
        corpus.iter().map(|_| std::sync::OnceLock::new()).collect();
    ExperimentSpec {
        name: "spgemm",
        title: "spgemm: two-phase system SpGEMM (symbolic/numeric), SSSR vs BASE".into(),
        columns: spgemm_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let i = p.idx.unwrap();
            let e = &corpus[i];
            let clusters = p.x.unwrap() as usize;
            let t = crate::formats::Csf::from_csr(&e.matrix);
            let ops = [Operand::Csf(&t), Operand::Csf(&t)];
            let ec = |k: usize| {
                ExecCfg::system(SystemCfg {
                    cluster: spgemm_cluster(),
                    ..SystemCfg::paper_system(k, k)
                })
                .with_limit(4_000_000_000)
            };
            let base = must_execute("smxsm_csf", Variant::Base, IdxWidth::U16, &ops, &ec(clusters));
            let sssr = must_execute("smxsm_csf", Variant::Sssr, IdxWidth::U16, &ops, &ec(clusters));
            let skew = match &sssr.detail {
                Detail::System { reduction, .. } => reduction.skew_cycles,
                _ => unreachable!("spgemm sweeps run on the system target"),
            };
            // 1-cluster SSSR reference; the sim is deterministic, so the
            // cell is value-identical whichever grid point fills it
            let one = *baselines[i].get_or_init(|| {
                if clusters == 1 {
                    sssr.report.cycles
                } else {
                    must_execute("smxsm_csf", Variant::Sssr, IdxWidth::U16, &ops, &ec(1))
                        .report
                        .cycles
                }
            });
            let scaling = one as f64 / sssr.report.cycles as f64;
            vec![Record::new("spgemm")
                .str("graph", e.name)
                .int("nodes", e.matrix.nrows as i64)
                .int("edges", (e.matrix.nnz() / 2) as i64)
                .int("clusters", clusters as i64)
                .int("base_cycles", base.report.cycles as i64)
                .int("sssr_cycles", sssr.report.cycles as i64)
                .num("speedup", base.report.cycles as f64 / sssr.report.cycles as f64)
                .num("scaling", scaling)
                .num("efficiency", scaling / clusters as f64)
                .int("skew_cycles", skew as i64)
                .int("payload", sssr.report.payload as i64)]
        }),
    }
}

// ======================================================================
// serve — the sparse serving engine sweep (policy × clusters × rate ×
// batch window × cache on/off)
// ======================================================================

/// Stream seed shared by every `serve` grid point: all configurations
/// serve the *same* request sequence, so policy/batching/cache effects
/// are directly comparable row to row.
pub const SERVE_SEED: u64 = 0x5E11E;

/// Batch arrival window (cycles) of the batched grid points.
pub const SERVE_WINDOW: u64 = 32_000;

/// Per-batch request cap (truncated to a power of two by the coalescer).
pub const SERVE_MAX_BATCH: usize = 16;

/// Hot-tenant share of the same-matrix-heavy stream, in percent.
pub const SERVE_HOT_PCT: u32 = 70;

/// One serving configuration of the `serve` grid.
#[derive(Clone, Debug)]
pub struct ServeCombo {
    pub policy: Policy,
    pub clusters: usize,
    /// Mean request inter-arrival gap in cycles (open-loop).
    pub mean_gap: f64,
    /// Batch window in cycles (0 = batching off).
    pub window: u64,
    pub cache: bool,
}

impl ServeCombo {
    fn label(&self) -> String {
        format!(
            "{}/c{}/g{}/w{}/{}",
            self.policy.name(),
            self.clusters,
            self.mean_gap as u64,
            self.window,
            if self.cache { "cache" } else { "nocache" }
        )
    }
}

/// The default `serve` grid. Quick mode sweeps 3 policies × {2, 4}
/// clusters × two arrival rates × {unbatched+cache, batched+cache,
/// unbatched+nocache}; `REPRO_FULL=1` adds 8 clusters, a third rate,
/// and the batched-uncached corner.
pub fn serve_combos() -> Vec<ServeCombo> {
    let clusters: Vec<usize> = if full_mode() { vec![2, 4, 8] } else { vec![2, 4] };
    let gaps: Vec<f64> = if full_mode() {
        vec![1000.0, 2000.0, 4000.0]
    } else {
        vec![1500.0, 3000.0]
    };
    let wc: Vec<(u64, bool)> = if full_mode() {
        vec![(0, true), (SERVE_WINDOW, true), (0, false), (SERVE_WINDOW, false)]
    } else {
        vec![(0, true), (SERVE_WINDOW, true), (0, false)]
    };
    let mut out = vec![];
    for policy in Policy::ALL {
        for &k in &clusters {
            for &mean_gap in &gaps {
                for &(window, cache) in &wc {
                    out.push(ServeCombo { policy, clusters: k, mean_gap, window, cache });
                }
            }
        }
    }
    out
}

/// Requests per serving grid point.
pub fn serve_requests() -> usize {
    if full_mode() {
        120
    } else {
        40
    }
}

fn serve_columns() -> Vec<Column> {
    vec![
        Column::new("policy", "policy", 9, ColFmt::Str),
        Column::new("clusters", "clus", 5, ColFmt::Int),
        Column::new("mean_gap", "gap", 6, ColFmt::Int),
        Column::new("window", "window", 7, ColFmt::Int),
        Column::new("cache", "cache", 6, ColFmt::StrR),
        Column::new("p50", "p50 cyc", 10, ColFmt::Int),
        Column::new("p95", "p95 cyc", 11, ColFmt::Int),
        Column::new("throughput_nnz", "nnz/cyc", 8, ColFmt::Fixed(3)),
        Column::new("utilization", "util", 6, ColFmt::Fixed(2)),
        Column::new("hit_rate", "hit", 6, ColFmt::Pct(0)),
        Column::new("batches", "batches", 8, ColFmt::Int),
    ]
}

/// Build a `serve` spec over an explicit combo grid (the default sweep
/// uses [`serve_combos`]; tests shrink the grid and request count).
/// Every grid point serves the same seeded stream through one
/// single-threaded engine run, so all simulated fields are
/// `--jobs`-invariant; only the per-policy `wall_ms` /
/// `wall_us_per_request` host stamps vary run to run.
pub fn spec_serve_with(requests: usize, combos: Vec<ServeCombo>) -> ExperimentSpec {
    let corpus = serve::serve_corpus();
    let points = combos
        .iter()
        .enumerate()
        .map(|(i, cb)| Point::at(i).label(cb.label()))
        .collect();
    ExperimentSpec {
        name: "serve",
        title: "serve: multi-tenant serving engine (policy x clusters x rate x batching x cache)"
            .into(),
        columns: serve_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cb = &combos[p.idx.unwrap()];
            let stream =
                StreamCfg::same_matrix_heavy(SERVE_SEED, requests, cb.mean_gap, SERVE_HOT_PCT);
            let reqs = serve::gen_stream(&stream, &corpus);
            let cfg = ServeCfg::new(cb.clusters, 1)
                .policy(cb.policy)
                .batched(cb.window, SERVE_MAX_BATCH)
                .caching(cb.cache);
            let out = serve::run_serve(&cfg, &corpus, &reqs)
                .unwrap_or_else(|e| panic!("serve[{}]: {e}", cb.label()));
            let s = out.summary;
            vec![Record::new("serve")
                .str("policy", cb.policy.name())
                .int("clusters", cb.clusters as i64)
                .int("channels", 1)
                .int("mean_gap", cb.mean_gap as i64)
                .int("window", cb.window as i64)
                .str("cache", if cb.cache { "on" } else { "off" })
                .int("requests", s.requests as i64)
                .int("p50", s.p50_latency as i64)
                .int("p95", s.p95_latency as i64)
                .int("p99", s.p99_latency as i64)
                .num("mean_latency", s.mean_latency)
                .num("mean_queue", s.mean_queue)
                .num("throughput_nnz", s.throughput_nnz)
                .num("utilization", s.utilization)
                .num("hit_rate", s.hit_rate)
                .int("upload_bytes", s.upload_bytes as i64)
                .int("batches", s.batches as i64)
                .num("avg_batch", s.avg_batch)
                .num("energy_uj", s.energy_j * 1e6)
                .int("makespan", s.makespan as i64)
                // engine-loop host wall time per policy; the timed
                // runner leaves this stamp alone (it only fills the key
                // when the measure closure didn't)
                .num("wall_ms", s.wall_ms)
                .num("wall_us_per_request", s.wall_us_per_request)]
        }),
    }
}

/// `serve`: the serving-engine sweep (`repro sweep serve` →
/// `BENCH_serve.json`).
pub fn spec_serve() -> ExperimentSpec {
    spec_serve_with(serve_requests(), serve_combos())
}

// ======================================================================
// chaos — adversarial serving scenarios (scenario × policy × cache)
// ======================================================================

/// Stream seed shared by every `chaos` grid point: each scenario's
/// stream is generated once per (scenario), so policy/cache effects
/// are directly comparable row to row within a scenario.
pub const CHAOS_SEED: u64 = 0xC4A05;

/// Mean inter-arrival gap (cycles) every chaos scenario shapes its
/// arrival process around (the `flood` scenario halves it, `burst`
/// compresses it 8× inside bursts — see [`Scenario::stream`]).
pub const CHAOS_GAP: f64 = 1500.0;

/// One `chaos` grid point.
#[derive(Clone, Debug)]
pub struct ChaosCombo {
    pub scenario: Scenario,
    pub policy: Policy,
    pub cache: bool,
}

impl ChaosCombo {
    fn label(&self) -> String {
        format!(
            "{}/{}/{}",
            self.scenario.name(),
            self.policy.name(),
            if self.cache { "cache" } else { "nocache" }
        )
    }
}

/// The default `chaos` grid: all six named scenarios × all dispatch
/// policies × cache on/off, every point batched
/// ([`SERVE_WINDOW`]/[`SERVE_MAX_BATCH`]) on a 2-cluster engine. The
/// `flood` points run under [`SloCfg::flood_default`] admission
/// control; the `closed` points run closed-loop.
pub fn chaos_combos() -> Vec<ChaosCombo> {
    let mut out = vec![];
    for scenario in Scenario::ALL {
        for policy in Policy::ALL {
            for cache in [true, false] {
                out.push(ChaosCombo { scenario, policy, cache });
            }
        }
    }
    out
}

/// Requests per chaos grid point.
pub fn chaos_requests() -> usize {
    if full_mode() {
        120
    } else {
        40
    }
}

fn chaos_columns() -> Vec<Column> {
    vec![
        Column::new("scenario", "scenario", 8, ColFmt::Str),
        Column::new("policy", "policy", 9, ColFmt::Str),
        Column::new("cache", "cache", 6, ColFmt::StrR),
        Column::new("p50", "p50 cyc", 10, ColFmt::Int),
        Column::new("p99", "p99 cyc", 11, ColFmt::Int),
        Column::new("throughput_nnz", "nnz/cyc", 8, ColFmt::Fixed(3)),
        Column::new("hit_rate", "hit", 6, ColFmt::Pct(0)),
        Column::new("evictions", "evict", 6, ColFmt::Int),
        Column::new("shed", "shed", 5, ColFmt::Int),
        Column::new("max_in_flight", "infl", 5, ColFmt::Int),
    ]
}

/// Build a `chaos` spec over an explicit combo grid (the default sweep
/// uses [`chaos_combos`]; tests shrink the grid and request count).
/// Each grid point regenerates its scenario's stream from
/// [`CHAOS_SEED`] and serves it through one single-threaded engine run
/// (churn events replayed as cache invalidations), so all simulated
/// fields are `--jobs`-invariant; only the host wall stamps vary.
pub fn spec_chaos_with(requests: usize, combos: Vec<ChaosCombo>) -> ExperimentSpec {
    let corpus = serve::serve_corpus();
    let points = combos
        .iter()
        .enumerate()
        .map(|(i, cb)| Point::at(i).label(cb.label()))
        .collect();
    ExperimentSpec {
        name: "chaos",
        title: "chaos: adversarial serving scenarios (scenario x policy x cache)".into(),
        columns: chaos_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cb = &combos[p.idx.unwrap()];
            let scfg = cb.scenario.stream(CHAOS_SEED, requests, CHAOS_GAP);
            let stream = serve::gen_stream_ex(&scfg, &corpus);
            let mut cfg = ServeCfg::new(2, 1)
                .policy(cb.policy)
                .batched(SERVE_WINDOW, SERVE_MAX_BATCH)
                .caching(cb.cache);
            if cb.scenario.slo_default() {
                let tenants = stream.reqs.iter().map(|r| r.tenant + 1).max().unwrap_or(0);
                cfg = cfg.slo(SloCfg::flood_default(tenants));
            }
            if let Some((clients, w)) = cb.scenario.closed_clients() {
                cfg = cfg.closed_loop(clients, w);
            }
            let out = serve::run_serve_stream(&cfg, &corpus, &stream)
                .unwrap_or_else(|e| panic!("chaos[{}]: {e}", cb.label()));
            let s = out.summary;
            let evictions: u64 = out.clusters.iter().map(|c| c.cache.evictions).sum();
            let invalidations: u64 = out.clusters.iter().map(|c| c.cache.invalidations).sum();
            vec![Record::new("chaos")
                .str("scenario", cb.scenario.name())
                .str("policy", cb.policy.name())
                .str("cache", if cb.cache { "on" } else { "off" })
                .int("clusters", 2)
                .int("channels", 1)
                .int("mean_gap", CHAOS_GAP as i64)
                .int("window", SERVE_WINDOW as i64)
                .int("requests", s.requests as i64)
                .int("p50", s.p50_latency as i64)
                .int("p95", s.p95_latency as i64)
                .int("p99", s.p99_latency as i64)
                .num("mean_latency", s.mean_latency)
                .num("throughput_nnz", s.throughput_nnz)
                .num("utilization", s.utilization)
                .num("hit_rate", s.hit_rate)
                .int("evictions", evictions as i64)
                .int("invalidations", invalidations as i64)
                .int("shed", s.shed_requests as i64)
                .int("violations", s.slo_violations as i64)
                .int("max_in_flight", s.max_in_flight as i64)
                .int("batches", s.batches as i64)
                .int("makespan", s.makespan as i64)
                .num("wall_ms", s.wall_ms)
                .num("wall_us_per_request", s.wall_us_per_request)]
        }),
    }
}

/// `chaos`: the adversarial-scenario sweep (`repro sweep chaos` →
/// `BENCH_chaos.json`).
pub fn spec_chaos() -> ExperimentSpec {
    spec_chaos_with(chaos_requests(), chaos_combos())
}

// ======================================================================
// pipeline — kernel-DAG applications with HBM-resident intermediates
// ======================================================================

/// One `pipeline` sweep point.
struct PipeCombo {
    app: &'static str,
    clusters: usize,
    variant: Variant,
}

/// apps x clusters x BASE/SSSR. With `clusters > 1` the System-capable
/// steps (sMxdV, sMxsV) run row-sharded; the dense tail stays
/// single-CC.
fn pipeline_combos() -> Vec<PipeCombo> {
    let mut out = vec![];
    for app in ["pagerank", "cg", "gnn", "stencil"] {
        for clusters in [1usize, 2] {
            for variant in [Variant::Base, Variant::Sssr] {
                out.push(PipeCombo { app, clusters, variant });
            }
        }
    }
    out
}

/// Build one shipped application over its deterministic sweep workload.
fn pipeline_app(app: &str) -> pipeline::Pipeline {
    match app {
        "pagerank" => {
            let g = if full_mode() { matgen::mycielskian(8) } else { matgen::mycielskian(6) };
            let p = pipeline::column_stochastic(&g);
            pipeline::pagerank(&p, 0.85, 0, 1e-6, 40)
        }
        "cg" => {
            let n = if full_mode() { 1024 } else { 256 };
            let a = pipeline::laplacian1d(n);
            let rhs = matgen::random_dense(0xC6, n);
            pipeline::cg(&a, &rhs, 1e-8, 60)
        }
        "gnn" => {
            let g = if full_mode() { matgen::mycielskian(8) } else { matgen::mycielskian(6) };
            let a = pipeline::column_stochastic(&g);
            let feats = matgen::random_dense(0xF0, a.nrows * 8);
            let bias = matgen::random_dense(0xB1, a.nrows * 8);
            pipeline::gnn_layer(&a, &feats, 3, 0.5, 0.5, &bias)
        }
        "stencil" => {
            let n = if full_mode() { 4096 } else { 1024 };
            let grid = matgen::random_dense(0x57, n);
            pipeline::stencil_steps(&Stencil1d::three_point(), &grid, 8)
        }
        other => panic!("unknown pipeline app {other}"),
    }
}

fn pipeline_columns() -> Vec<Column> {
    vec![
        Column::new("app", "app", 9, ColFmt::Str),
        Column::new("clusters", "clus", 5, ColFmt::Int),
        Column::new("variant", "variant", 8, ColFmt::Str),
        Column::new("iters", "iters", 6, ColFmt::Int),
        Column::new("cycles", "cycles", 12, ColFmt::Int),
        Column::new("bytes_resident", "res B", 10, ColFmt::Int),
        Column::new("bytes_roundtrip", "rt B", 11, ColFmt::Int),
        Column::new("byte_reduction", "red x", 7, ColFmt::Fixed(2)),
        Column::new("footprint", "hbm B", 10, ColFmt::Int),
    ]
}

/// `pipeline`: every kernel-DAG application, run twice per grid point —
/// HBM-resident and per-step round-tripped. The two runs must be
/// bit-identical (same kernels, same order, same data; only transfer
/// accounting differs), so `byte_reduction` is exactly the measured
/// host↔HBM saving of residency. `BENCH_pipeline.json` additionally
/// carries the per-iteration cycle/byte breakdown and the residual
/// trajectory as comma-joined fields.
pub fn spec_pipeline() -> ExperimentSpec {
    let combos = pipeline_combos();
    let points = combos
        .iter()
        .enumerate()
        .map(|(i, cb)| {
            Point::at(i).label(format!("{} k{} {}", cb.app, cb.clusters, cb.variant.name()))
        })
        .collect();
    ExperimentSpec {
        name: "pipeline",
        title: "pipeline: kernel-DAG apps, HBM-resident vs round-tripped intermediates".into(),
        columns: pipeline_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cb = &combos[p.idx.unwrap()];
            let dag = pipeline_app(cb.app);
            let pcfg =
                PipeCfg::new(cb.variant, IdxWidth::U16).on_system(cb.clusters, cb.clusters);
            let res = dag
                .run(&pcfg)
                .unwrap_or_else(|e| panic!("pipeline[{} k{}]: {e}", cb.app, cb.clusters));
            let rt = dag
                .run(&pcfg.clone().roundtrip())
                .unwrap_or_else(|e| panic!("pipeline[{} k{}]: {e}", cb.app, cb.clusters));
            assert_eq!(
                res.outputs, rt.outputs,
                "{}: resident and round-tripped runs must be bit-identical",
                cb.app
            );
            assert_eq!(res.cycles, rt.cycles);
            let join = |it: Vec<String>| it.join(",");
            let iter_cycles =
                join(res.per_iter.iter().map(|t| t.cycles.to_string()).collect());
            let iter_bytes =
                join(res.per_iter.iter().map(|t| t.host_bytes.to_string()).collect());
            let residuals =
                join(res.residuals.iter().map(|r| format!("{r:.3e}")).collect());
            vec![Record::new("pipeline")
                .str("app", cb.app)
                .int("clusters", cb.clusters as i64)
                .str("variant", cb.variant.name())
                .int("iters", res.iters as i64)
                .int("steps", res.steps as i64)
                .int("cycles", res.cycles as i64)
                .int("bytes_resident", res.host_bytes as i64)
                .int("bytes_roundtrip", rt.host_bytes as i64)
                .num(
                    "byte_reduction",
                    rt.host_bytes as f64 / res.host_bytes.max(1) as f64,
                )
                .int("hbm_bytes", res.hbm_bytes as i64)
                .int("footprint", res.plan.footprint as i64)
                .int("naive_bytes", res.plan.naive_bytes as i64)
                .str("iter_cycles", iter_cycles)
                .str("iter_host_bytes", iter_bytes)
                .str("residuals", residuals)]
        }),
    }
}

// ======================================================================
// Fig. 7 — area and timing (analytical model)
// ======================================================================

/// The streamer configurations of Fig. 7b, in ascending area order.
fn fig7_streamer_configs() -> Vec<(&'static str, StreamerCfg)> {
    use SlotKind::*;
    vec![
        ("S+S+S (baseline)", StreamerCfg::baseline_ssr()),
        ("I+S+S", StreamerCfg { slots: vec![Issr, Ssr, Ssr], union: false }),
        ("I+I+S", StreamerCfg { slots: vec![Issr, Issr, Ssr], union: false }),
        ("I*+I*+S", StreamerCfg { slots: vec![IssrCmp, IssrCmp, Ssr], union: false }),
        ("I*+I*+E", StreamerCfg { slots: vec![IssrCmp, IssrCmp, Essr], union: false }),
        ("I*+I*+E+union (default)", StreamerCfg::default_sssr()),
    ]
}

pub fn spec_fig7b() -> ExperimentSpec {
    let configs = fig7_streamer_configs();
    let points = configs
        .iter()
        .enumerate()
        .map(|(i, (name, _))| Point::at(i).label(*name))
        .collect();
    ExperimentSpec {
        name: "fig7b",
        title: "Fig. 7b: streamer configurations".into(),
        columns: vec![
            Column::new("config", "config", 26, ColFmt::Str),
            Column::new("area_kge", "area kGE", 10, ColFmt::Fixed(1)),
            Column::new("min_period_ps", "min period ps", 14, ColFmt::Fixed(0)),
        ],
        points,
        measure: Box::new(move |p: &Point| {
            let (name, cfg) = &configs[p.idx.unwrap()];
            vec![Record::new("fig7b")
                .str("config", *name)
                .num("area_kge", streamer_area(cfg))
                .num("min_period_ps", streamer_min_period_ps(cfg))]
        }),
    }
}

pub fn spec_fig7c() -> ExperimentSpec {
    let targets = [450.0, 500.0, 550.0, 600.0, 700.0, 800.0, 1000.0];
    let points = targets.iter().map(|&t| Point::default().x(t)).collect();
    ExperimentSpec {
        name: "fig7c",
        title: "Fig. 7c: area vs clock target (default streamer)".into(),
        columns: vec![
            Column::new("target_ps", "target ps", 10, ColFmt::Fixed(0)),
            Column::new("area_kge", "area kGE", 10, ColFmt::Fixed(1)),
        ],
        points,
        measure: Box::new(|p: &Point| {
            let t = p.x.unwrap();
            let cfg = StreamerCfg::default_sssr();
            vec![Record::new("fig7c")
                .num("target_ps", t)
                .num("area_kge", crate::model::area::streamer_area_at_period(&cfg, t))]
        }),
    }
}

/// The Fig. 7 companion line: modeled SSSR area overhead at cluster level.
pub fn print_fig7_footer() {
    let oh = crate::model::area::cluster_overhead_fraction(8);
    println!("\ncluster area overhead (8 cores): {:.2} %", oh * 100.0);
}

// ======================================================================
// Fig. 8 — energy (activity-scaled model over cluster runs)
// ======================================================================

fn spec_fig8(name: &'static str, title: &str, kernel: &'static str) -> ExperimentSpec {
    let corpus = corpus_selection();
    let points = corpus
        .iter()
        .enumerate()
        .map(|(i, e)| Point::at(i).label(e.name))
        .collect();
    ExperimentSpec {
        name,
        title: title.into(),
        columns: energy_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let cfg = ClusterCfg::paper_cluster();
            let em = EnergyModel::default();
            let e = &corpus[p.idx.unwrap()];
            let ec = ExecCfg::cluster(cfg.clone());
            let runs: Vec<(&'static str, KernelRun, u64)> = match kernel {
                "smxdv" => {
                    let b = matgen::random_dense(1500, e.matrix.ncols);
                    let ops = [Operand::Csr(&e.matrix), Operand::Dense(&b)];
                    let base = must_execute("smxdv", Variant::Base, IdxWidth::U16, &ops, &ec);
                    let sssr = must_execute("smxdv", Variant::Sssr, IdxWidth::U16, &ops, &ec);
                    let nnz = e.matrix.nnz() as u64;
                    vec![("base", base, nnz), ("sssr", sssr, nnz)]
                }
                "smxsv" => {
                    let nnz_v = ((0.01 * e.matrix.ncols as f64) as usize).max(1);
                    let b = matgen::random_spvec(1600, e.matrix.ncols, nnz_v);
                    let ops = [Operand::Csr(&e.matrix), Operand::SpVec(&b)];
                    let base = must_execute("smxsv", Variant::Base, IdxWidth::U16, &ops, &ec);
                    let sssr = must_execute("smxsv", Variant::Sssr, IdxWidth::U16, &ops, &ec);
                    // Fig. 8b normalizes per *matrix nonzero*
                    let nnz = e.matrix.nnz() as u64;
                    vec![("base", base, nnz), ("sssr", sssr, nnz)]
                }
                _ => unreachable!(),
            };
            runs.into_iter()
                .map(|(variant, run, ops)| {
                    let er = em.estimate(&run.report.stats, ops);
                    Record::new(name)
                        .str("matrix", e.name)
                        .str("kernel", kernel)
                        .str("variant", variant)
                        .num("pj_per_op", er.pj_per_op)
                        .num("power_mw", er.avg_power_w * 1e3)
                        .num("total_uj", er.total_j * 1e6)
                })
                .collect()
        }),
    }
}

pub fn spec_fig8a() -> ExperimentSpec {
    spec_fig8("fig8a", "Fig. 8a: cluster sMxdV energy", "smxdv")
}

pub fn spec_fig8b() -> ExperimentSpec {
    spec_fig8("fig8b", "Fig. 8b: cluster sMxsV energy (d_v=1%)", "smxsv")
}

// ======================================================================
// Tables 2 & 3 — comparisons against the literature
// ======================================================================

/// Literature rows of Table 2 (peak FP64 sM×dV utilization).
pub const TABLE2_LITERATURE: &[(&str, &str, &str, f64)] = &[
    ("CVR [33]", "Xeon Phi 7250", "CVR", 0.0069),
    ("Zhang et al. [34]", "Xeon Phi 7230", "SELL-like", 0.015),
    ("Regu2D [35]", "Xeon Gold 6132", "Regu2D", 0.031),
    ("Alappat et al. [7]", "A64FX", "SELL-C-sigma", 0.047),
    ("Tsai et al. [37]", "V100", "CSR", 0.016),
    ("Merrill et al. [38]", "K40", "CSR", 0.020),
    ("TileSpMV [39]", "A100", "tile-adapt.", 0.029),
    ("Tsai et al. [37]", "Radeon VII", "CSR", 0.032),
    ("cuSPARSE [40]", "GTX 1080 Ti", "CSR", 0.17),
    ("TileSpMV [39]", "Titan RTX", "tile-adapt.", 0.27),
];

pub fn spec_table2() -> ExperimentSpec {
    let points = (0..TABLE2_LITERATURE.len()).map(Point::at).collect();
    ExperimentSpec {
        name: "table2",
        title: "Table 2: FP64 sMxdV peak FP utilization".into(),
        columns: vec![
            Column::new("work", "work", 22, ColFmt::Str),
            Column::new("platform", "platform", 16, ColFmt::Str),
            Column::new("format", "format", 14, ColFmt::Str),
            Column::new("peak_util", "peak util", 10, ColFmt::Pct(2)),
        ],
        points,
        measure: Box::new(|p: &Point| {
            let (work, platform, format, util) = TABLE2_LITERATURE[p.idx.unwrap()];
            vec![Record::new("table2")
                .str("work", work)
                .str("platform", platform)
                .str("format", format)
                .num("peak_util", util)]
        }),
    }
}

/// Our measured peak cluster sM×dV utilization (Table 2 bottom row):
/// best over the Fig. 5a corpus sweep.
pub fn table2_ours(fig5a_records: &[Record]) -> f64 {
    fig5a_records
        .iter()
        .filter_map(|r| r.f64("utilization"))
        .fold(0.0, f64::max)
}

/// Table 2 spec plus its full record set: the literature rows and the
/// measured "ours" bottom row. Goes through the same Record layer as
/// every figure so `--json` captures the headline number too.
pub fn table2_records(ours: f64) -> (ExperimentSpec, Vec<Record>) {
    let spec = spec_table2();
    let mut recs = spec.run(1);
    let mut bottom = Record::new("table2")
        .str("work", "SSSRs (ours, sim)")
        .str("platform", "Snitch + SSSRs")
        .str("format", "CSR")
        .num("peak_util", ours);
    bottom.point = spec.points.len();
    recs.push(bottom);
    (spec, recs)
}

/// Render Table 2 including the measured bottom row and the headline
/// ratios against the best CPU/GPU results.
pub fn print_table2(ours: f64) {
    let (spec, recs) = table2_records(ours);
    spec.print(&recs);
    let best_cpu = 0.047;
    let best_gpu = 0.27;
    println!(
        "-> vs best CPU {:.1}x, vs best GPU {:.1}x",
        ours / best_cpu,
        ours / best_gpu
    );
}

/// Table 3 literature rows: (work, open-source, one-sided, two-sided,
/// format flexibility, sparsity flexibility, area kGE if published).
const TABLE3_LITERATURE: &[(&str, bool, bool, bool, &str, &str, Option<f64>)] = &[
    ("SVE S/G [29]", false, true, false, "M", "H", None),
    ("KNL S/G [30]", false, true, false, "M", "H", None),
    ("UVE [31]", false, true, false, "M", "H", Some(72.0)),
    ("Gong et al. [32]", false, true, false, "L", "L", Some(31.0)),
    ("Prodigy [8]", true, true, false, "M", "H", Some(10.0)),
    ("SpZip [41]", false, true, false, "M", "H", Some(116.0)),
    ("Z. Wang et al. [9]", false, true, false, "M", "H", None),
    ("SparseCore [6]", false, false, true, "H", "H", Some(619.0)),
    ("A100 [17]", false, true, false, "M", "L", None),
    ("ExTensor [12]", false, false, true, "M", "H", None),
];

pub fn spec_table3() -> ExperimentSpec {
    // literature rows plus the measured "ours" row
    let points = (0..TABLE3_LITERATURE.len() + 1).map(Point::at).collect();
    ExperimentSpec {
        name: "table3",
        title: "Table 3: hardware designs".into(),
        columns: vec![
            Column::new("work", "work", 20, ColFmt::Str),
            Column::new("open", "open", 5, ColFmt::StrR),
            Column::new("one_sided", "1-sided", 9, ColFmt::StrR),
            Column::new("two_sided", "2-sided", 9, ColFmt::StrR),
            Column::new("format_flex", "fmt", 7, ColFmt::StrR),
            Column::new("sparsity_flex", "sparsity", 9, ColFmt::StrR),
            Column::new("area_kge", "kGE", 9, ColFmt::Fixed(0)),
        ],
        points,
        measure: Box::new(|p: &Point| {
            let i = p.idx.unwrap();
            let (work, open, one, two, fmt, sparsity, area) = if i < TABLE3_LITERATURE.len() {
                TABLE3_LITERATURE[i]
            } else {
                let ours_area = streamer_area(&StreamerCfg::default_sssr());
                ("SSSRs (ours)", true, true, true, "H", "H", Some(ours_area))
            };
            let yn = |b: bool| if b { "yes" } else { "no" };
            vec![Record::new("table3")
                .str("work", work)
                .str("open", yn(open))
                .str("one_sided", yn(one))
                .str("two_sided", yn(two))
                .str("format_flex", fmt)
                .str("sparsity_flex", sparsity)
                .opt_num("area_kge", area)]
        }),
    }
}

// ======================================================================
// simperf — simulator wall-clock throughput (not a paper figure)
// ======================================================================

fn simperf_columns() -> Vec<Column> {
    vec![
        Column::new("workload", "workload", 22, ColFmt::Str),
        Column::new("cycles", "cycles", 12, ColFmt::Int),
        Column::new("nnz", "nnz", 9, ColFmt::Int),
        Column::new("wall_ms", "wall ms", 10, ColFmt::Fixed(1)),
        Column::new("sim_mcycles_per_s", "Mcyc/s", 9, ColFmt::Fixed(2)),
    ]
}

/// `simperf`: simulated-cycles-per-wall-second on the three
/// characteristic workloads of `benches/sim_throughput.rs` — single-CC
/// SSSR sM×dV (streamer-heavy), single-CC BASE sV×sV (core-heavy), and
/// the eight-core-cluster SSSR sM×dV (full memory system). The
/// `wall_ms` / `sim_mcycles_per_s` columns fill in when the spec runs
/// under a timed runner ([`Runner::timed`], as `repro sweep simperf`
/// and the `sim_throughput` bench do); the modeled `cycles` column is
/// deterministic either way and doubles as a coarse golden guard.
pub fn spec_simperf() -> ExperimentSpec {
    let labels = ["single_cc_sssr_smxdv", "single_cc_base_svxsv", "cluster_sssr_smxdv"];
    let points = labels.iter().enumerate().map(|(i, l)| Point::at(i).label(*l)).collect();
    ExperimentSpec {
        name: "simperf",
        title: "simperf: simulator throughput on characteristic workloads".into(),
        columns: simperf_columns(),
        points,
        measure: Box::new(move |p: &Point| {
            let (label, cycles, nnz) = match p.idx.unwrap() {
                0 => {
                    let m = matgen::random_csr(1, 512, 1024, 40_000);
                    let b = matgen::random_dense(2, 1024);
                    let (_, rep) = run_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b);
                    (labels[0], rep.cycles, m.nnz())
                }
                1 => {
                    let a = matgen::random_spvec(3, 40_000, 8000);
                    let c = matgen::random_spvec(4, 40_000, 8000);
                    let (_, rep) = run_svxsv(Variant::Base, IdxWidth::U32, &a, &c);
                    (labels[1], rep.cycles, a.nnz() + c.nnz())
                }
                _ => {
                    let m = matgen::mycielskian(10);
                    let b = matgen::random_dense(5, m.ncols);
                    let run = run_cluster_smxdv(
                        Variant::Sssr,
                        IdxWidth::U16,
                        &m,
                        &b,
                        &ClusterCfg::paper_cluster(),
                    );
                    (labels[2], run.report.cycles, m.nnz())
                }
            };
            vec![Record::new("simperf")
                .str("workload", label)
                .int("cycles", cycles as i64)
                .int("nnz", nnz as i64)]
        }),
    }
}

// ======================================================================
// spec registry
// ======================================================================

/// Every figure sweep as a (name, constructor) pair, in `repro all`
/// order (the paper figures plus the system-layer `scale` family, the
/// CSF/graph `graph` sweep, the two-phase `spgemm` scaling sweep, the
/// serving-engine `serve` sweep, the adversarial-scenario `chaos`
/// sweep, and the kernel-DAG `pipeline` sweep).
/// Construction generates the sweep's shared workloads (corpus,
/// operands) eagerly, so build one spec at a time and drop it before
/// the next — materializing all twenty-two at
/// once holds every workload in memory simultaneously. Tables 2/3 are available via
/// [`spec_table2`]/[`spec_table3`] (Table 2's bottom row derives from
/// Fig. 5a records, see [`table2_ours`]).
pub const SPEC_BUILDERS: &[(&str, fn() -> ExperimentSpec)] = &[
    ("fig4a", spec_fig4a),
    ("fig4b", spec_fig4b),
    ("fig4c", spec_fig4c),
    ("fig4d", spec_fig4d),
    ("fig4e", spec_fig4e),
    ("fig4f", spec_fig4f),
    ("fig5a", spec_fig5a),
    ("fig5b", spec_fig5b),
    ("fig6a", spec_fig6a),
    ("fig6b", spec_fig6b),
    ("fig7b", spec_fig7b),
    ("fig7c", spec_fig7c),
    ("fig8a", spec_fig8a),
    ("fig8b", spec_fig8b),
    ("scale", spec_scale),
    ("scale_sv", spec_scale_sv),
    ("graph", spec_graph),
    ("spgemm", spec_spgemm),
    ("serve", spec_serve),
    ("chaos", spec_chaos),
    ("pipeline", spec_pipeline),
    ("simperf", spec_simperf),
];

/// Look up one figure spec constructor by name (`"fig4a"`, `"fig7b"`, …).
pub fn spec_builder(name: &str) -> Option<fn() -> ExperimentSpec> {
    SPEC_BUILDERS.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

/// Look up and build one figure spec by name.
pub fn spec_by_name(name: &str) -> Option<ExperimentSpec> {
    spec_builder(name).map(|f| f())
}

/// All figure sweep names, space-joined (help/error text).
pub fn spec_names() -> String {
    SPEC_BUILDERS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::Runner;

    #[test]
    fn table2_literature_data_hygiene() {
        assert_eq!(TABLE2_LITERATURE.len(), 10);
        assert!(TABLE2_LITERATURE.iter().all(|(_, _, _, u)| *u > 0.0 && *u < 1.0));
    }

    #[test]
    fn fig7_spec_covers_configs() {
        let spec = spec_fig7b();
        let rows = spec.run(1);
        assert_eq!(rows.len(), 6);
        let first = rows[0].f64("area_kge").unwrap();
        let last = rows.last().unwrap().f64("area_kge").unwrap();
        assert!(first < last);
    }

    #[test]
    fn repeated_fiber_allows_duplicates() {
        let f = repeated_idx_fiber(1, 256, 1000);
        assert_eq!(f.nnz(), 1000);
        assert!(f.idcs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn table3_has_ours_with_modeled_area() {
        let spec = spec_table3();
        let rows = spec.run(1);
        assert_eq!(rows.len(), 11);
        let ours = rows.last().unwrap();
        assert_eq!(ours.str_of("work"), Some("SSSRs (ours)"));
        for key in ["open", "one_sided", "two_sided"] {
            assert_eq!(ours.str_of(key), Some("yes"));
        }
        assert!((29.0..31.0).contains(&ours.f64("area_kge").unwrap()));
    }

    #[test]
    fn analytical_specs_are_jobs_invariant() {
        // fig7b/7c are pure analytical-model sweeps: cheap enough for a
        // real end-to-end determinism check of the parallel runner.
        for spec in [spec_fig7b(), spec_fig7c(), spec_table2(), spec_table3()] {
            let serial = Runner::new(1).run(&spec);
            let par = Runner::new(4).run(&spec);
            assert_eq!(serial, par, "{} diverged under --jobs 4", spec.name);
        }
    }

    #[test]
    fn spec_registry_is_consistent() {
        assert_eq!(SPEC_BUILDERS.len(), 22);
        for (n, build) in SPEC_BUILDERS {
            let s = build();
            assert_eq!(s.name, *n);
            assert!(!s.points.is_empty(), "{} has an empty grid", s.name);
            assert!(!s.columns.is_empty(), "{} has no table layout", s.name);
        }
        assert!(spec_by_name("fig4a").is_some());
        assert!(spec_by_name("nope").is_none());
    }
}
