//! Regenerates the two-phase SpGEMM scaling sweep (`spgemm`:
//! symbolic/numeric CSF SpGEMM squaring the graph corpus on the system
//! target, SSSR vs BASE at 1/2/4/8 clusters) through the parallel
//! experiment engine and writes `BENCH_spgemm.json` next to the other
//! bench trajectories. Quick graphs by default; REPRO_FULL=1 for the
//! corpus-sized instances.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    let spec = h::spec_by_name("spgemm").expect("spgemm spec registered");
    let recs = runner.run(&spec);
    spec.print(&recs);
    let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
    println!("[wrote {}]", path.display());
    println!("\n[fig_spgemm bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
