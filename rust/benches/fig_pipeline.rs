//! Regenerates the kernel-DAG pipeline sweep (`pipeline`: four
//! iterative applications — PageRank, CG, GNN layer, stencil
//! time-stepping — × clusters × BASE/SSSR, each run HBM-resident and
//! host-round-tripping with bit-identity checked) and writes
//! `BENCH_pipeline.json` next to the other bench trajectories. Quick
//! problem sizes by default; REPRO_FULL=1 for the paper-size grid.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = h::spec_by_name("pipeline").expect("pipeline spec registered");
    let recs = Runner::new(0).run(&spec);
    spec.print(&recs);
    let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
    println!("[wrote {}]", path.display());
    println!("\n[fig_pipeline bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
