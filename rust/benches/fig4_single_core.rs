//! Regenerates Fig. 4 (single-CC kernel evaluation, §4.1):
//! 4a sVxdV utilization, 4b sV+dV utilization, 4c sMxdV speedups,
//! 4d sVxsV speedups, 4e sV+sV speedups, 4f sMxsV speedups.
//! Quick sweeps by default; REPRO_FULL=1 for the paper-size sweeps.
//! Grid points run in parallel (one worker per core); records are
//! identical to a serial run.
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    // lazy constructors: one spec's captured workloads live at a time
    for name in ["fig4a", "fig4b", "fig4c", "fig4d", "fig4e", "fig4f"] {
        let spec = h::spec_by_name(name).expect("fig4 spec registered");
        let recs = runner.run(&spec);
        spec.print(&recs);
    }
    println!("\n[fig4 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
