//! Regenerates Fig. 4 (single-CC kernel evaluation, §4.1):
//! 4a sVxdV utilization, 4b sV+dV utilization, 4c sMxdV speedups,
//! 4d sVxsV speedups, 4e sV+sV speedups, 4f sMxsV speedups.
//! Quick sweeps by default; REPRO_FULL=1 for the paper-size sweeps.
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    h::print_util_rows("Fig. 4a: CC sVxdV FPU utilization vs nonzeros", &h::fig4a());
    h::print_util_rows("Fig. 4b: CC sV+dV FPU utilization vs nonzeros", &h::fig4b());
    h::print_speedup_rows("Fig. 4c: CC sMxdV speedups over BASE", &h::fig4c());
    h::print_density_rows("Fig. 4d: CC sVxsV speedup vs densities (len 20k/60k)", &h::fig4d());
    h::print_density_rows("Fig. 4e: CC sV+sV speedup vs densities", &h::fig4e());
    h::print_matsv_rows("Fig. 4f: CC sMxsV speedups over BASE", &h::fig4f());
    println!("\n[fig4 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
