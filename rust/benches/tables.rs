//! Regenerates Tables 2 and 3 (§5 comparison to related work).
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let runner = Runner::new(0);
    let rows = runner.run(&h::spec_fig5a());
    h::print_table2(h::table2_ours(&rows));
    let spec3 = h::spec_table3();
    let t3 = runner.run(&spec3);
    spec3.print(&t3);
}
