//! Regenerates Tables 2 and 3 (§5 comparison to related work).
use sssr::harness as h;

fn main() {
    let rows = h::fig5a();
    h::print_table2(h::table2_ours(&rows));
    h::print_table3();
}
