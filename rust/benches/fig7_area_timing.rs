//! Regenerates Fig. 7 (streamer area and timing, §4.3) from the
//! GF12LP+-calibrated analytical model.
use sssr::harness as h;

fn main() {
    h::print_fig7();
}
