//! Regenerates Fig. 7 (streamer area and timing, §4.3) from the
//! GF12LP+-calibrated analytical model.
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let runner = Runner::new(0);
    for spec in [h::spec_fig7b(), h::spec_fig7c()] {
        let recs = runner.run(&spec);
        spec.print(&recs);
    }
    h::print_fig7_footer();
}
