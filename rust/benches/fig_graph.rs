//! Regenerates the CSF tensor / graph kernel sweep (`graph`: triangle
//! counting and CSF SpGEMM, SSSR vs BASE over the graph corpus) through
//! the parallel experiment engine and writes `BENCH_graph.json` next to
//! the other bench trajectories. Quick graphs by default; REPRO_FULL=1
//! for the corpus-sized instances.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    let spec = h::spec_by_name("graph").expect("graph spec registered");
    let recs = runner.run(&spec);
    spec.print(&recs);
    let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
    println!("[wrote {}]", path.display());
    println!("\n[fig_graph bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
