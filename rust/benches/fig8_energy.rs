//! Regenerates Fig. 8 (cluster energy estimates, §4.4): cluster runs
//! feed the activity-scaled energy model.
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    for name in ["fig8a", "fig8b"] {
        let spec = h::spec_by_name(name).expect("fig8 spec registered");
        let recs = runner.run(&spec);
        spec.print(&recs);
    }
    println!("\n[fig8 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
