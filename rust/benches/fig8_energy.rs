//! Regenerates Fig. 8 (cluster energy estimates, §4.4): cluster runs
//! feed the activity-scaled energy model.
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    h::print_energy_rows("Fig. 8a: cluster sMxdV energy", &h::fig8("smxdv"));
    h::print_energy_rows("Fig. 8b: cluster sMxsV energy (d_v=1%)", &h::fig8("smxsv"));
    println!("\n[fig8 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
