//! Regenerates the system-layer scaling sweeps (`scale` = multi-cluster
//! SpMV, `scale_sv` = SpMSpV) through the parallel experiment engine and
//! writes `BENCH_scale.json` / `BENCH_scale_sv.json` next to the other
//! bench trajectories. Quick sweeps by default; REPRO_FULL=1 for the
//! full corpus and channel counts.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    // lazy constructors: one spec's captured workloads live at a time
    for name in ["scale", "scale_sv"] {
        let spec = h::spec_by_name(name).expect("scale spec registered");
        let recs = runner.run(&spec);
        spec.print(&recs);
        let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
        println!("[wrote {}]", path.display());
    }
    println!("\n[fig_scale bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
