//! Regenerates Fig. 5 (eight-core cluster scaleouts with HBM2E +
//! interconnect models, §4.2) through the parallel experiment engine.
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    let spec_a = h::spec_fig5a();
    let a = runner.run(&spec_a);
    spec_a.print(&a);
    let spec_b = h::spec_fig5b();
    let b = runner.run(&spec_b);
    spec_b.print(&b);
    let peak = h::table2_ours(&a);
    println!("\npeak cluster sMxdV FPU utilization: {:.1} % (paper: 46.8 %)", peak * 100.0);
    println!("[fig5 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
