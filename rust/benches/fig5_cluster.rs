//! Regenerates Fig. 5 (eight-core cluster scaleouts with HBM2E +
//! interconnect models, §4.2).
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let a = h::fig5a();
    h::print_cluster_rows("Fig. 5a: cluster sMxdV speedups (16-bit)", &a);
    let b = h::fig5b();
    h::print_cluster_rows("Fig. 5b: cluster sMxsV speedups (16-bit)", &b);
    let peak = h::table2_ours(&a);
    println!("\npeak cluster sMxdV FPU utilization: {:.1} % (paper: 46.8 %)", peak * 100.0);
    println!("[fig5 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
