//! Simulator performance benchmark (the §Perf hot path): measures
//! simulated cycles per wall-second on the three characteristic
//! workloads of `harness::spec_simperf` (single-CC streamer-heavy,
//! single-CC core-heavy, eight-core cluster), prints the table, writes
//! `BENCH_simperf.json`, and — when a committed baseline exists —
//! fails (exit 1) if any workload regressed to below 70 % of its
//! baseline Mcycles/s.
//!
//! Knobs:
//! - `SIMPERF_JSON=<dir>`: where `BENCH_simperf.json` is written
//!   (default: the repo root, i.e. the committed location).
//! - `SIMPERF_BASELINE=<file>`: baseline to regress against (default:
//!   the committed `BENCH_simperf.json` at the repo root).

use std::collections::HashMap;
use std::path::PathBuf;

use sssr::experiments::{write_json, Record, Runner};
use sssr::harness::spec_simperf;

/// Repo root: the committed `BENCH_simperf.json` lives next to the
/// `rust/` package directory.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn baseline_path() -> PathBuf {
    std::env::var_os("SIMPERF_BASELINE")
        .map(PathBuf::from)
        .unwrap_or_else(|| repo_root().join("BENCH_simperf.json"))
}

fn out_dir() -> PathBuf {
    std::env::var_os("SIMPERF_JSON").map(PathBuf::from).unwrap_or_else(repo_root)
}

/// `workload -> Mcycles/s` of a BENCH_simperf.json file (records
/// without a rate — e.g. written by an untimed run — are skipped).
fn load_rates(path: &PathBuf) -> Option<HashMap<String, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut rates = HashMap::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let rec = match Record::from_json_line(line) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("simperf: skipping malformed baseline line ({e})");
                continue;
            }
        };
        if let (Some(w), Some(rate)) = (rec.str_of("workload"), rec.f64("sim_mcycles_per_s")) {
            rates.insert(w.to_string(), rate);
        }
    }
    Some(rates)
}

fn main() {
    // The throughput gate measures the simulator proper: force tracing
    // off even if SIM_TRACE is set in the environment (recording is
    // observation-only, but buffer pushes cost wall clock, and this
    // bench's numbers feed the regression baseline). Clearing the env
    // var before the first enabled() query covers the runner's worker
    // threads too, which a thread-local override would not.
    std::env::set_var("SIM_TRACE", "0");
    let spec = spec_simperf();
    // One worker: the points time-share one host core each anyway, and
    // serial runs keep the wall-clock numbers comparable across hosts.
    let recs = Runner::new(1).timed(true).run(&spec);
    spec.print(&recs);

    // Regress against the committed baseline BEFORE overwriting it.
    let baseline = baseline_path();
    let verdict = match load_rates(&baseline) {
        None => {
            println!(
                "\nsimperf: NO BASELINE at {} — recording this run as the new baseline \
                 (no regression check performed)",
                baseline.display()
            );
            Ok(())
        }
        Some(rates) => {
            let mut failed = false;
            for r in &recs {
                let (Some(w), Some(now)) = (r.str_of("workload"), r.f64("sim_mcycles_per_s"))
                else {
                    continue;
                };
                match rates.get(w) {
                    Some(&base) if base > 0.0 => {
                        let ratio = now / base;
                        println!(
                            "simperf: {w}: {now:.2} Mcycles/s vs baseline {base:.2} ({:+.0}%)",
                            (ratio - 1.0) * 100.0
                        );
                        if ratio < 0.7 {
                            eprintln!(
                                "simperf: REGRESSION on {w}: {now:.2} < 70% of baseline {base:.2}"
                            );
                            failed = true;
                        }
                    }
                    _ => println!("simperf: {w}: no baseline rate recorded — skipping check"),
                }
            }
            if failed {
                Err(())
            } else {
                Ok(())
            }
        }
    };

    match write_json(&out_dir(), &spec, &recs) {
        Ok(path) => println!("simperf: wrote {}", path.display()),
        Err(e) => eprintln!("simperf: could not write BENCH_simperf.json: {e}"),
    }

    if verdict.is_err() {
        std::process::exit(1);
    }
}
