//! Simulator performance benchmark (the §Perf hot path): measures
//! simulated cycles per wall-second for the three characteristic
//! workloads. This is the number the EXPERIMENTS.md §Perf log tracks.
use std::time::Instant;

use sssr::coordinator::run_cluster_smxdv;
use sssr::kernels::driver::{run_smxdv, run_svxsv};
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::sim::ClusterCfg;

fn main() {
    // 1) single-CC SSSR sMxdV (streamer-heavy)
    let m = matgen::random_csr(1, 512, 1024, 40_000);
    let b = matgen::random_dense(2, 1024);
    let t = Instant::now();
    let (_, rep) = run_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b);
    let dt = t.elapsed().as_secs_f64();
    println!(
        "single-CC sssr smxdv : {:>10} cycles in {:>6.2}s = {:>7.2} Mcycles/s",
        rep.cycles, dt, rep.cycles as f64 / dt / 1e6
    );

    // 2) single-CC BASE svxsv (core-heavy)
    let a = matgen::random_spvec(3, 40_000, 8000);
    let c = matgen::random_spvec(4, 40_000, 8000);
    let t = Instant::now();
    let (_, rep) = run_svxsv(Variant::Base, IdxWidth::U32, &a, &c);
    let dt = t.elapsed().as_secs_f64();
    println!(
        "single-CC base svxsv : {:>10} cycles in {:>6.2}s = {:>7.2} Mcycles/s",
        rep.cycles, dt, rep.cycles as f64 / dt / 1e6
    );

    // 3) eight-core cluster SSSR sMxdV (full system)
    let m = matgen::mycielskian(10);
    let b = matgen::random_dense(5, m.ncols);
    let cfg = ClusterCfg::paper_cluster();
    let t = Instant::now();
    let run = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg);
    let dt = t.elapsed().as_secs_f64();
    println!(
        "cluster  sssr smxdv : {:>10} cycles in {:>6.2}s = {:>7.2} Mcycles/s",
        run.report.cycles, dt, run.report.cycles as f64 / dt / 1e6
    );
}
