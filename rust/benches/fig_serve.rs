//! Regenerates the serving-engine sweep (`serve`: policy × clusters ×
//! arrival rate × batch window × cache on/off over the same-matrix-heavy
//! request stream) through the parallel experiment engine and writes
//! `BENCH_serve.json` next to the other bench trajectories. Quick grid
//! by default; REPRO_FULL=1 for the full cluster/rate grid and the
//! longer stream.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = h::spec_by_name("serve").expect("serve spec registered");
    let recs = Runner::new(0).run(&spec);
    spec.print(&recs);
    let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
    println!("[wrote {}]", path.display());
    println!("\n[fig_serve bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
