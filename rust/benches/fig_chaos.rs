//! Regenerates the adversarial-scenario sweep (`chaos`: scenario ×
//! policy × cache on/off — MMPP bursts, tenant churn with cache
//! invalidation replay, hot-set rotation, the SLO-guarded same-matrix
//! flood, and closed-loop load) through the parallel experiment engine
//! and writes `BENCH_chaos.json` next to the other bench trajectories.
//! Quick stream by default; REPRO_FULL=1 for the longer stream.
use std::path::Path;

use sssr::experiments::{write_json, Runner};
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = h::spec_by_name("chaos").expect("chaos spec registered");
    let recs = Runner::new(0).run(&spec);
    spec.print(&recs);
    let path = write_json(Path::new("."), &spec, &recs).expect("writing BENCH json");
    println!("[wrote {}]", path.display());
    println!("\n[fig_chaos bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
