//! Regenerates Fig. 6 (DRAM bandwidth and interconnect latency
//! sensitivity, §4.2.1) on the Mycielskian peak-speedup matrix.
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    h::print_sensitivity_rows(
        "Fig. 6a: speedup vs DRAM channel bandwidth",
        "Gb/s/pin",
        &h::fig6a(),
    );
    h::print_sensitivity_rows(
        "Fig. 6b: speedup vs on-chip interconnect latency",
        "cycles",
        &h::fig6b(),
    );
    println!("\n[fig6 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
