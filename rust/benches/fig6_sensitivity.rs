//! Regenerates Fig. 6 (DRAM bandwidth and interconnect latency
//! sensitivity, §4.2.1) on the Mycielskian peak-speedup matrix.
use sssr::experiments::Runner;
use sssr::harness as h;

fn main() {
    let t0 = std::time::Instant::now();
    let runner = Runner::new(0);
    for name in ["fig6a", "fig6b"] {
        let spec = h::spec_by_name(name).expect("fig6 spec registered");
        let recs = runner.run(&spec);
        spec.print(&recs);
    }
    println!("\n[fig6 bench wall time: {:.1}s]", t0.elapsed().as_secs_f64());
}
