//! Property-based differential tests for `formats::ops`: a seeded,
//! hand-rolled randomized sweep (no external property-testing deps)
//! checking every sparse reference op — including the CSF union,
//! intersection, and row-wise SpGEMM oracles — against naive dense
//! implementations, across dimension, density, and duplicate-pattern
//! corners the uniform generators rarely hit.

use sssr::formats::{ops, Csf, Csr, SpVec};
use sssr::util::Pcg;

const CASES: usize = 120;

/// Generator with deliberately adversarial corners: empty and singleton
/// dimensions, zero and full density, and (for operand pairs) identical,
/// subset, and disjoint index patterns.
struct Gen {
    r: Pcg,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { r: Pcg::new(seed) }
    }

    fn dim(&mut self) -> usize {
        match self.r.below(5) {
            0 => 1,
            1 => 2,
            2 => 1 + self.r.below(8) as usize,
            _ => 1 + self.r.below(120) as usize,
        }
    }

    /// Nonzero count biased toward the corners (0, 1, full).
    fn nnz(&mut self, dim: usize) -> usize {
        match self.r.below(5) {
            0 => 0,
            1 => 1.min(dim),
            2 => dim,
            _ => self.r.below(dim as u64 + 1) as usize,
        }
    }

    fn spvec(&mut self, dim: usize) -> SpVec {
        let nnz = self.nnz(dim);
        let idcs: Vec<u32> = self.r.distinct_sorted(nnz, dim).iter().map(|&x| x as u32).collect();
        let vals: Vec<f64> = (0..nnz).map(|_| self.r.normal()).collect();
        SpVec::new(dim, idcs, vals)
    }

    /// A partner for `a`: same pattern, subset, disjoint-ish, or fresh —
    /// the duplicate-pattern corners of the set-algebra ops.
    fn partner(&mut self, a: &SpVec) -> SpVec {
        match self.r.below(4) {
            0 => SpVec {
                dim: a.dim,
                idcs: a.idcs.clone(),
                vals: a.idcs.iter().map(|_| self.r.normal()).collect(),
            },
            1 => {
                // random subset of a's pattern
                let mut idcs = vec![];
                let mut vals = vec![];
                for &i in &a.idcs {
                    if self.r.below(2) == 0 {
                        idcs.push(i);
                        vals.push(self.r.normal());
                    }
                }
                SpVec { dim: a.dim, idcs, vals }
            }
            2 => {
                // complement-leaning pattern: indices a does not use
                let used: Vec<bool> = {
                    let mut u = vec![false; a.dim];
                    for &i in &a.idcs {
                        u[i as usize] = true;
                    }
                    u
                };
                let mut idcs = vec![];
                let mut vals = vec![];
                for i in 0..a.dim {
                    if !used[i] && self.r.below(3) == 0 {
                        idcs.push(i as u32);
                        vals.push(self.r.normal());
                    }
                }
                SpVec { dim: a.dim, idcs, vals }
            }
            _ => self.spvec(a.dim),
        }
    }

    fn dense(&mut self, dim: usize) -> Vec<f64> {
        (0..dim).map(|_| self.r.normal()).collect()
    }

    fn csr(&mut self, nrows: usize, ncols: usize) -> Csr {
        let nnz = self.nnz(nrows * ncols);
        let cells = self.r.distinct_sorted(nnz, nrows * ncols);
        let t: Vec<(u32, u32, f64)> = cells
            .iter()
            .map(|&cell| {
                let (r, c) = ((cell as usize / ncols) as u32, (cell as usize % ncols) as u32);
                (r, c, self.r.normal())
            })
            .collect();
        Csr::from_triplets(nrows, ncols, t)
    }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

fn assert_dense_close(got: &[f64], want: &[f64], what: &str, case: usize) {
    assert_eq!(got.len(), want.len(), "{what} length, case {case}");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(close(*g, *w), "{what}[{i}]: got {g}, want {w} (case {case})");
    }
}

#[test]
fn vector_ops_match_dense_references() {
    let mut g = Gen::new(0xA11CE);
    for case in 0..CASES {
        let dim = g.dim();
        let a = g.spvec(dim);
        let b = g.partner(&a);
        let d = g.dense(dim);
        let (da, db) = (a.to_dense(), b.to_dense());

        // sV x dV
        let want: f64 = da.iter().zip(&d).map(|(x, y)| x * y).sum();
        assert!(close(ops::svxdv(&a, &d), want), "svxdv case {case}");

        // sV + dV (in place)
        let mut got = d.clone();
        ops::svpdv(&a, &mut got);
        let want: Vec<f64> = da.iter().zip(&d).map(|(x, y)| x + y).collect();
        assert_dense_close(&got, &want, "svpdv", case);

        // sV o dV keeps a's pattern
        let prod = ops::svodv(&a, &d);
        assert_eq!(prod.idcs, a.idcs, "svodv pattern, case {case}");
        let want: Vec<f64> = da.iter().zip(&d).map(|(x, y)| x * y).collect();
        assert_dense_close(&prod.to_dense(), &want, "svodv", case);

        // sV x sV
        let want: f64 = da.iter().zip(&db).map(|(x, y)| x * y).sum();
        assert!(close(ops::svxsv(&a, &b), want), "svxsv case {case}");

        // sV + sV: dense agreement plus the union-pattern invariant
        let sum = ops::svpsv(&a, &b);
        sum.validate().expect("svpsv result invalid");
        let want: Vec<f64> = da.iter().zip(&db).map(|(x, y)| x + y).collect();
        assert_dense_close(&sum.to_dense(), &want, "svpsv", case);
        let union: Vec<u32> = {
            let mut u: Vec<u32> = a.idcs.iter().chain(&b.idcs).copied().collect();
            u.sort_unstable();
            u.dedup();
            u
        };
        assert_eq!(sum.idcs, union, "svpsv union pattern, case {case}");

        // sV o sV: dense agreement plus the intersection-pattern invariant
        let prod = ops::svosv(&a, &b);
        prod.validate().expect("svosv result invalid");
        let want: Vec<f64> = da.iter().zip(&db).map(|(x, y)| x * y).collect();
        assert_dense_close(&prod.to_dense(), &want, "svosv", case);
        let inter: Vec<u32> =
            a.idcs.iter().copied().filter(|i| b.idcs.contains(i)).collect();
        assert_eq!(prod.idcs, inter, "svosv intersection pattern, case {case}");

        // scale keeps the pattern even at alpha = 0
        let z = ops::svscale(0.0, &a);
        assert_eq!(z.idcs, a.idcs);
        assert!(z.vals.iter().all(|&v| v == 0.0));
    }
}

#[test]
fn matrix_ops_match_dense_references() {
    let mut g = Gen::new(0xB0B);
    for case in 0..CASES {
        let (n, k) = (g.dim(), g.dim());
        let m = g.csr(n, k);
        let dm = m.to_dense();
        let v = g.dense(k);
        let sv = g.spvec(k);

        // sM x dV
        let got = ops::smxdv(&m, &v);
        let want: Vec<f64> = dm
            .iter()
            .map(|row| row.iter().zip(&v).map(|(x, y)| x * y).sum())
            .collect();
        assert_dense_close(&got, &want, "smxdv", case);

        // sM x dM (small inner dense width)
        let cols = 1 + g.r.below(4) as usize;
        let d = g.dense(k * cols);
        let got = ops::smxdm(&m, &d, cols);
        let mut want = vec![0.0; n * cols];
        for i in 0..n {
            for x in 0..k {
                for j in 0..cols {
                    want[i * cols + j] += dm[i][x] * d[x * cols + j];
                }
            }
        }
        assert_dense_close(&got, &want, "smxdm", case);

        // sM x sV
        let got = ops::smxsv(&m, &sv);
        let dsv = sv.to_dense();
        let want: Vec<f64> = dm
            .iter()
            .map(|row| row.iter().zip(&dsv).map(|(x, y)| x * y).sum())
            .collect();
        assert_dense_close(&got, &want, "smxsv", case);

        // sM x sM (inner dataflow, dense result)
        let p = g.dim().min(24);
        let b = g.csr(k, p);
        let db = b.to_dense();
        let got = ops::smxsm_inner(&m, &sssr::formats::Csc::from_csr(&b));
        let mut want = vec![0.0; n * p];
        for i in 0..n {
            for x in 0..k {
                for j in 0..p {
                    want[i * p + j] += dm[i][x] * db[x][j];
                }
            }
        }
        assert_dense_close(&got, &want, "smxsm_inner", case);
    }
}

#[test]
fn csf_ops_match_dense_references() {
    let mut g = Gen::new(0xC5F);
    for case in 0..CASES {
        let (n, k, p) = (g.dim(), g.dim(), g.dim().min(40));
        let a = Csf::from_csr(&g.csr(n, k));
        let b = Csf::from_csr(&g.csr(n, k));
        let (da, db) = (a.to_dense(), b.to_dense());

        // format round trips
        assert_eq!(Csf::from_dense(&da), a, "csf dense roundtrip, case {case}");
        assert_eq!(a.to_csr().ptrs, a.row_directory(), "row directory, case {case}");

        // CSF + CSF
        let sum = ops::csf_add(&a, &b);
        sum.validate().expect("csf_add result invalid");
        let ds = sum.to_dense();
        for i in 0..n {
            for j in 0..k {
                assert!(
                    close(ds[i][j], da[i][j] + db[i][j]),
                    "csf_add ({i},{j}), case {case}"
                );
            }
        }

        // CSF o CSF
        let prod = ops::csf_mul(&a, &b);
        prod.validate().expect("csf_mul result invalid");
        let dp = prod.to_dense();
        for i in 0..n {
            for j in 0..k {
                assert!(
                    close(dp[i][j], da[i][j] * db[i][j]),
                    "csf_mul ({i},{j}), case {case}"
                );
            }
        }
        // intersection never stores rows absent from either operand
        for &r in &prod.row_idcs {
            assert!(a.row_idcs.contains(&r) && b.row_idcs.contains(&r));
        }

        // CSF x CSF row-wise SpGEMM
        let c = Csf::from_csr(&g.csr(k, p));
        let dc = c.to_dense();
        let got = ops::smxsm_csf(&a, &c);
        got.validate().expect("smxsm_csf result invalid");
        let dg = got.to_dense();
        for i in 0..n {
            for j in 0..p {
                let want: f64 = (0..k).map(|x| da[i][x] * dc[x][j]).sum();
                assert!(close(dg[i][j], want), "smxsm_csf ({i},{j}), case {case}");
            }
        }
        // the flop count bounds the result size
        assert!(ops::smxsm_csf_flops(&a, &c) >= got.nnz() as u64);
    }
}

#[test]
fn csf_set_ops_duplicate_pattern_corners() {
    // exactly equal patterns: add keeps the shared directory, mul too
    let mut g = Gen::new(0xD0D0);
    for case in 0..40 {
        let (n, k) = (g.dim(), g.dim());
        let a = Csf::from_csr(&g.csr(n, k));
        let twin = Csf {
            vals: a.vals.iter().map(|v| v * 2.0).collect(),
            ..a.clone()
        };
        let sum = ops::csf_add(&a, &twin);
        assert_eq!(sum.row_idcs, a.row_idcs, "case {case}");
        assert_eq!(sum.col_idcs, a.col_idcs, "case {case}");
        for (s, v) in sum.vals.iter().zip(&a.vals) {
            assert!(close(*s, 3.0 * v), "case {case}");
        }
        let prod = ops::csf_mul(&a, &twin);
        assert_eq!(prod.col_idcs, a.col_idcs, "case {case}");
        // disjoint row sets: add concatenates, mul annihilates
        let empty = Csf::empty(n, k);
        assert_eq!(ops::csf_add(&a, &empty), a, "case {case}");
        assert_eq!(ops::csf_mul(&a, &empty).nfibers(), 0, "case {case}");
    }
}

/// The two-phase SpGEMM contract: the structure-only symbolic pass
/// predicts the numeric output exactly — per output fiber and in total
/// — so the numeric pass can stream into exactly-sized allocations with
/// zero over-allocation. Swept over the adversarial corner generator
/// plus the graph shapes the system sweep actually squares (rmat-style
/// power-law adjacencies and mycielskians).
#[test]
fn spgemm_symbolic_sizing_is_exact() {
    fn assert_symbolic_exact(a: &Csf, b: &Csf, what: &str) {
        let (sizes, total) = ops::smxsm_csf_symbolic(a, b);
        let c = ops::smxsm_csf(a, b);
        assert_eq!(sizes.len(), a.nfibers(), "{what}: one prediction per A fiber");
        assert_eq!(total, sizes.iter().sum::<usize>(), "{what}: total is the fiber sum");
        assert_eq!(total, c.nnz(), "{what}: total output size prediction");
        // per fiber: nonzero predictions are exact lengths in A's fiber
        // order; zero predictions produce no output fiber at all
        let mut f_out = 0usize;
        for (fa, (ra, _, _)) in a.fibers().enumerate() {
            if sizes[fa] == 0 {
                continue;
            }
            let (rc, ic, _) = c.fiber(f_out);
            assert_eq!(rc, ra, "{what}: output fiber order follows A");
            assert_eq!(ic.len(), sizes[fa], "{what}: fiber {fa} size");
            f_out += 1;
        }
        assert_eq!(f_out, c.nfibers(), "{what}: no unpredicted output fibers");
    }

    // corner-case random rectangles (empty/singleton/full densities)
    let mut g = Gen::new(0x57A7);
    for case in 0..CASES {
        let (n, k, m) = (g.dim(), g.dim(), g.dim());
        let a = Csf::from_csr(&g.csr(n, k));
        let b = Csf::from_csr(&g.csr(k, m));
        assert_symbolic_exact(&a, &b, &format!("corner case {case}"));
    }
    // the sweep corpus shapes: adjacency squaring A*A
    for (name, m) in [
        ("rmat6", sssr::matgen::undirected_graph(0xB0, 6, 5)),
        ("rmat7", sssr::matgen::undirected_graph(0xB1, 7, 4)),
        ("myc6", sssr::matgen::mycielskian(6)),
        ("myc7", sssr::matgen::mycielskian(7)),
    ] {
        let t = Csf::from_csr(&m);
        assert_symbolic_exact(&t, &t, name);
    }
}
