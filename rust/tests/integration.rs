//! Cross-module integration tests: kernel variants against each other,
//! cluster against single core, property sweeps over random workloads,
//! and (when `make artifacts` has run) the PJRT golden path.
//!
//! These complement the per-module unit tests with whole-stack
//! invariants. The random-input sweeps play the role proptest would
//! (the offline build vendors no proptest): deterministic PRNG, many
//! cases, shrink-free but reproducible by seed.

use sssr::coordinator::{run_cluster_smxdv, run_cluster_smxsv};
use sssr::experiments::{ColFmt, Column, ExperimentSpec, Point, Record, Runner};
use sssr::formats::{ops, SpVec};
use sssr::kernels::driver::*;
use sssr::kernels::multi::{run_system_smxdv, run_system_smxsv};
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::sim::{ClusterCfg, SystemCfg};
use sssr::util::Pcg;

const WIDTHS: [IdxWidth; 2] = [IdxWidth::U16, IdxWidth::U32];

/// Property: every kernel variant computes identical results on random
/// fibers (the drivers verify vs the oracle internally; this asserts
/// cross-variant agreement too, incl. cycle sanity).
#[test]
fn property_all_variants_agree_on_random_vectors() {
    let mut r = Pcg::new(2024);
    for case in 0..12 {
        let dim = 64 + r.below(2000) as usize;
        let nnz_a = r.below(dim as u64 / 2) as usize;
        let nnz_b = r.below(dim as u64 / 2) as usize;
        let a = matgen::random_spvec(3000 + case, dim, nnz_a.max(1));
        let b = matgen::random_spvec(4000 + case, dim, nnz_b.max(1));
        let d = matgen::random_dense(5000 + case, dim);
        for iw in WIDTHS {
            let (x0, r0) = run_svxdv(Variant::Base, iw, &a, &d, false);
            let (x1, r1) = run_svxdv(Variant::Ssr, iw, &a, &d, false);
            let (x2, r2) = run_svxdv(Variant::Sssr, iw, &a, &d, false);
            assert!((x0 - x1).abs() < 1e-9 && (x1 - x2).abs() < 1e-9);
            assert!(r2.cycles <= r1.cycles && r1.cycles <= r0.cycles + 64,
                "variant cycle ordering violated: {} {} {}", r0.cycles, r1.cycles, r2.cycles);
            let (y0, _) = run_svxsv(Variant::Base, iw, &a, &b);
            let (y1, _) = run_svxsv(Variant::Sssr, iw, &a, &b);
            assert!((y0 - y1).abs() < 1e-9 * y0.abs().max(1.0));
        }
    }
}

/// Property: union/intersection result fibers are valid sparse vectors
/// with the exact set-algebra patterns.
#[test]
fn property_union_intersection_patterns() {
    let mut r = Pcg::new(7);
    for case in 0..12 {
        let dim = 32 + r.below(800) as usize;
        let a = matgen::random_spvec(6000 + case, dim, (r.below(dim as u64 / 2) as usize).max(1));
        let b = matgen::random_spvec(7000 + case, dim, (r.below(dim as u64 / 2) as usize).max(1));
        let (u, _) = run_svpsv(Variant::Sssr, IdxWidth::U16, &a, &b);
        let (i, _) = run_svosv(Variant::Sssr, IdxWidth::U16, &a, &b);
        u.validate().unwrap();
        i.validate().unwrap();
        // |A ∪ B| + |A ∩ B| == |A| + |B|
        assert_eq!(u.nnz() + i.nnz(), a.nnz() + b.nnz());
        // intersection ⊆ both operands; union ⊇ both
        let au: std::collections::BTreeSet<u32> = a.idcs.iter().copied().collect();
        let bu: std::collections::BTreeSet<u32> = b.idcs.iter().copied().collect();
        for &x in &i.idcs {
            assert!(au.contains(&x) && bu.contains(&x));
        }
        for &x in &a.idcs {
            assert!(u.idcs.binary_search(&x).is_ok());
        }
    }
}

/// Property: the eight-core cluster computes the same sM×dV/sM×sV as
/// the single core, for random matrices spanning empty to dense rows.
#[test]
fn property_cluster_matches_single_core() {
    let cfg = ClusterCfg::paper_cluster();
    let mut r = Pcg::new(11);
    for case in 0..4 {
        let rows = 64 + r.below(256) as usize;
        let cols = 128 + r.below(512) as usize;
        let nnz = (rows + r.below((rows * 8) as u64) as usize).min(rows * cols / 2);
        let m = matgen::random_csr(8000 + case, rows, cols, nnz);
        let b = matgen::random_dense(9000 + case, cols);
        let cl = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg);
        let (single, _) = run_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b);
        for (x, y) in cl.result.iter().zip(&single) {
            assert!((x - y).abs() < 1e-9 * y.abs().max(1.0));
        }
        let sv = matgen::random_spvec(9500 + case, cols, (cols / 10).max(1));
        let cl = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &m, &sv, &cfg);
        let single = ops::smxsv(&m, &sv);
        for (x, y) in cl.result.iter().zip(&single) {
            assert!((x - y).abs() < 1e-9 * y.abs().max(1.0));
        }
    }
}

/// System-layer regression (public API): a one-cluster system is
/// cycle-identical to the standalone cluster on both sharded kernels,
/// and multi-cluster scaling shows shared-channel contention.
#[test]
fn system_layer_regression_and_contention() {
    let m = matgen::random_csr(12_000, 300, 400, 9000);
    let b = matgen::random_dense(12_001, 400);
    let sv = matgen::random_spvec(12_002, 400, 40);
    let ccfg = ClusterCfg::paper_cluster();

    let alone_dv = run_cluster_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &ccfg);
    let sys_dv =
        run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &SystemCfg::paper_system(1, 1));
    assert_eq!(sys_dv.report.cycles, alone_dv.report.cycles, "smxdv cycle identity");
    assert_eq!(sys_dv.result, alone_dv.result);

    let alone_sv = run_cluster_smxsv(Variant::Sssr, IdxWidth::U16, &m, &sv, &ccfg);
    let sys_sv =
        run_system_smxsv(Variant::Sssr, IdxWidth::U16, &m, &sv, &SystemCfg::paper_system(1, 1));
    assert_eq!(sys_sv.report.cycles, alone_sv.report.cycles, "smxsv cycle identity");
    assert_eq!(sys_sv.result, alone_sv.result);

    // four clusters on one shared channel: strictly sub-linear scaling
    let four =
        run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &SystemCfg::paper_system(4, 1));
    let speedup = alone_dv.report.cycles as f64 / four.report.cycles as f64;
    assert!(speedup < 4.0, "shared channel cannot scale linearly: {speedup}x");
    assert_eq!(four.shards.len(), 4);
    assert_eq!(four.reduction.combine_flops, 0);
}

/// Edge cases that have historically broken sparse kernels.
#[test]
fn edge_cases_sparse_kernels() {
    let dim = 64;
    let d = matgen::random_dense(1, dim);
    // single element at position 0 / at the last position
    for pos in [0u32, (dim - 1) as u32] {
        let v = SpVec::new(dim, vec![pos], vec![2.5]);
        let (x, _) = run_svxdv(Variant::Sssr, IdxWidth::U16, &v, &d, false);
        assert!((x - 2.5 * d[pos as usize]).abs() < 1e-12);
    }
    // adjacent duplicated patterns in matrices with empty first/last rows
    let m = sssr::formats::Csr::new(
        3,
        8,
        vec![0, 0, 2, 2],
        vec![0, 7],
        vec![1.0, -1.0],
    );
    let d8 = matgen::random_dense(3, 8);
    for v in [Variant::Base, Variant::Ssr, Variant::Sssr] {
        let (c, _) = run_smxdv(v, IdxWidth::U16, &m, &d8);
        assert_eq!(c[0], 0.0);
        assert_eq!(c[2], 0.0);
        assert!((c[1] - (d8[0] - d8[7])).abs() < 1e-12);
    }
    // fully dense "sparse" vector
    let full = SpVec::new(16, (0..16).collect(), vec![1.0; 16]);
    let d16 = matgen::random_dense(2, 16);
    let (x, _) = run_svxdv(Variant::Sssr, IdxWidth::U16, &full, &d16, false);
    let want: f64 = d16.iter().sum();
    assert!((x - want).abs() < 1e-9);
}

/// The Fig. 4 headline calibrations (§4.1): BASE 1/9, SSR 1/7 issue
/// bounds on sV×dV; SSSR near the arbitration limits.
#[test]
fn calibration_issue_bounds_and_arbitration_limits() {
    let dim = 8192;
    let a = matgen::random_spvec(42, dim, 4096);
    let b = matgen::random_dense(43, dim);
    let (_, base) = run_svxdv(Variant::Base, IdxWidth::U16, &a, &b, false);
    let (_, ssr) = run_svxdv(Variant::Ssr, IdxWidth::U16, &a, &b, false);
    assert!((0.10..0.12).contains(&base.utilization), "BASE {}", base.utilization);
    assert!((0.13..0.16).contains(&ssr.utilization), "SSR {}", ssr.utilization);
    for (iw, limit) in [(IdxWidth::U16, 0.80), (IdxWidth::U32, 2.0 / 3.0)] {
        let (_, r) = run_svxdv(Variant::Sssr, iw, &a, &b, true);
        assert!(
            r.utilization > 0.88 * limit && r.utilization <= limit + 0.01,
            "SSSR {:?} utilization {} vs limit {}",
            iw,
            r.utilization,
            limit
        );
    }
}

/// The experiment engine drives real simulator runs deterministically:
/// a small sV×dV sweep produces byte-identical JSON under any --jobs.
#[test]
fn experiment_engine_is_deterministic_over_real_sims() {
    let spec = ExperimentSpec {
        name: "itest",
        title: "integration determinism sweep".into(),
        columns: vec![
            Column::new("nnz", "nnz", 8, ColFmt::Int),
            Column::new("utilization", "util", 8, ColFmt::Fixed(3)),
        ],
        points: [8usize, 32, 96].iter().map(|&n| Point::default().nnz(n)).collect(),
        measure: Box::new(|p| {
            let nnz = p.nnz.unwrap();
            let dim = 512;
            let a = matgen::random_spvec(40_000 + nnz as u64, dim, nnz);
            let b = matgen::random_dense(41_000, dim);
            let (dot, rep) = run_svxdv(Variant::Sssr, IdxWidth::U16, &a, &b, false);
            vec![Record::new("itest")
                .int("nnz", nnz as i64)
                .num("dot", dot)
                .int("cycles", rep.cycles as i64)
                .num("utilization", rep.utilization)]
        }),
    };
    let serial: Vec<String> =
        Runner::new(1).run(&spec).iter().map(|r| r.to_json_line()).collect();
    let parallel: Vec<String> =
        Runner::new(3).run(&spec).iter().map(|r| r.to_json_line()).collect();
    assert_eq!(serial, parallel);
    assert_eq!(serial.len(), 3);
    // and the lines parse back as records
    for line in &serial {
        let r = Record::from_json_line(line).unwrap();
        assert!(r.f64("cycles").unwrap() > 0.0);
    }
}

/// PJRT golden path (needs `--features xla`; skipped when artifacts are
/// absent so `cargo test` works before `make artifacts`).
#[cfg(feature = "xla")]
#[test]
fn golden_models_match_simulator() {
    let path = std::path::Path::new("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping golden test: run `make artifacts` first");
        return;
    }
    let rt = sssr::runtime::Runtime::load(path).expect("loading artifacts");
    let n = sssr::runtime::golden::verify_all(&rt).expect("golden verification");
    assert!(n >= 7, "expected >= 7 golden checks, ran {n}");
}

/// Property: N-cluster System SpGEMM is bit-identical to the single-CC
/// `smxsm_csf` run — the nnz-balanced fiber sharding plus deterministic
/// CSF concatenation must not reorder or re-associate a single flop —
/// and `tricnt`'s sharded scalar reduction reproduces the single-CC
/// count to the last mantissa bit. Swept over seeded rmat-style and
/// mycielskian adjacencies at several cluster counts.
#[test]
fn property_system_spgemm_and_tricnt_bit_identical_to_single_cc() {
    use sssr::formats::Csf;
    use sssr::kernels::api::{self, Detail, ExecCfg, Operand, Value};

    let corpus = [
        ("rmat6", matgen::undirected_graph(0xD1, 6, 6)),
        ("myc6", matgen::mycielskian(6)),
    ];
    let big = ClusterCfg { tcdm_bytes: 1 << 20, ..ClusterCfg::paper_cluster() };
    for (name, g) in &corpus {
        let t = Csf::from_csr(g);
        let csf_ops = [Operand::Csf(&t), Operand::Csf(&t)];
        let tri_ops = [Operand::Csr(g)];
        for variant in [Variant::Base, Variant::Sssr] {
            let single = api::must_execute(
                "smxsm_csf", variant, IdxWidth::U16, &csf_ops, &ExecCfg::single_cc(),
            );
            let Value::Csf(want) = single.output else { unreachable!() };
            let tri_single = api::must_execute(
                "tricnt", variant, IdxWidth::U16, &tri_ops, &ExecCfg::single_cc(),
            );
            let Value::Scalar(tri_want) = tri_single.output else { unreachable!() };
            for clusters in [2usize, 4] {
                let sys = SystemCfg {
                    cluster: big.clone(),
                    ..SystemCfg::paper_system(clusters, clusters)
                };
                let run = api::must_execute(
                    "smxsm_csf", variant, IdxWidth::U16, &csf_ops, &ExecCfg::system(sys.clone()),
                );
                let Value::Csf(got) = run.output else { unreachable!() };
                assert_eq!(
                    got, want,
                    "{name} {variant:?}: {clusters}-cluster SpGEMM diverged from single-CC"
                );
                let Detail::System { shards, .. } = run.detail else { unreachable!() };
                assert_eq!(shards.len(), clusters);
                let tri = api::must_execute(
                    "tricnt", variant, IdxWidth::U16, &tri_ops, &ExecCfg::system(sys),
                );
                let Value::Scalar(tri_got) = tri.output else { unreachable!() };
                assert_eq!(
                    tri_got.to_bits(),
                    tri_want.to_bits(),
                    "{name} {variant:?}: {clusters}-cluster tricnt diverged from single-CC"
                );
            }
        }
    }
}
