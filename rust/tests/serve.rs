//! Serving-engine integration tests: determinism of the `serve` and
//! `chaos` sweep records across `--jobs`, bit-identity of coalesced
//! `smxdm` batches vs the per-request `smxdv` runs they replace, the
//! acceptance regressions pinning the scenario orderings
//! `BENCH_serve.json` / `BENCH_chaos.json` report (batching +
//! cache-affinity beats unbatched FIFO under steady and burst arrivals;
//! churn raises eviction counters; the flood tenant absorbs all SLO
//! sheds; closed-loop bounds in-flight work), the
//! `AFFINITY_REORDER_WINDOW` fairness guard under rotation/flood, and a
//! seeded operand-cache property test against a shadow LRU model.

use sssr::experiments::{Record, Runner};
use sssr::harness::{
    self, ChaosCombo, ServeCombo, CHAOS_GAP, CHAOS_SEED, SERVE_HOT_PCT, SERVE_MAX_BATCH,
    SERVE_SEED, SERVE_WINDOW,
};
use sssr::kernels::api::{must_execute, ExecCfg, Operand};
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::serve::sched::AFFINITY_REORDER_WINDOW;
use sssr::serve::{self, batch, Form, OperandCache, Policy, Scenario, ServeCfg, SloCfg, StreamCfg};

/// Differential: a coalesced `smxdm` batch returns bit-identical
/// columns to the standalone `smxdv` runs it replaces (both variants).
/// This is the contract that lets the serving engine batch without
/// changing any tenant-visible number.
#[test]
fn smxdm_batch_bit_identical_to_smxdv_runs() {
    let m = matgen::random_csr(0xB0, 48, 64, 320);
    let vecs: Vec<Vec<f64>> = (0..4u64).map(|j| matgen::random_dense(0xB1 + j, 64)).collect();
    let cfg = ExecCfg::single_cc();
    for variant in [Variant::Base, Variant::Sssr] {
        let singles: Vec<Vec<f64>> = vecs
            .iter()
            .map(|b| {
                let ops = [Operand::Csr(&m), Operand::Dense(b)];
                must_execute("smxdv", variant, IdxWidth::U16, &ops, &cfg)
                    .output
                    .as_dense()
                    .unwrap()
                    .to_vec()
            })
            .collect();
        let refs: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        let d = batch::interleave(&refs);
        let ops = [Operand::Csr(&m), Operand::Dense(&d), Operand::Scalar(2)];
        let run = must_execute("smxdm", variant, IdxWidth::U16, &ops, &cfg);
        let cols = batch::scatter(run.output.as_dense().unwrap(), m.nrows, 4);
        for (j, (got, want)) in cols.iter().zip(&singles).enumerate() {
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{variant:?}: batch column {j} differs from its smxdv run at row {i}"
                );
            }
        }
    }
}

/// Engine-level differential: serving the same stream with batching on
/// vs off yields bit-identical per-request results — coalescing changes
/// timing only.
#[test]
fn engine_batching_preserves_results_bitwise() {
    let corpus = serve::serve_corpus();
    let stream = StreamCfg::same_matrix_heavy(SERVE_SEED, 32, 1500.0, SERVE_HOT_PCT);
    let reqs = serve::gen_stream(&stream, &corpus);
    let unbatched = serve::run_serve(&ServeCfg::new(2, 1), &corpus, &reqs).unwrap();
    let batched = serve::run_serve(
        &ServeCfg::new(2, 1).batched(SERVE_WINDOW, SERVE_MAX_BATCH),
        &corpus,
        &reqs,
    )
    .unwrap();
    assert!(
        batched.summary.batches > 0,
        "the overloaded hot stream must actually coalesce"
    );
    assert!(batched.summary.batched_requests >= 2 * batched.summary.batches);
    for (a, b) in unbatched.requests.iter().zip(&batched.requests) {
        assert_eq!(a.id, b.id);
        match (&a.result, &b.result) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len(), "request {}", a.id);
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "request {} result diverged at row {i}",
                        a.id
                    );
                }
            }
            _ => panic!("request {}: result presence diverged", a.id),
        }
    }
}

/// Acceptance regression: on the same-matrix-heavy stream, the batching
/// + cache-affinity configuration beats unbatched FIFO on both p95
/// simulated-cycle latency and nnz/cycle throughput. The two
/// configurations are exactly the quick-grid rows
/// `fifo/c2/g1500/w0/cache` and `affinity/c2/g1500/w32000/cache` of
/// `spec_serve`, so this pins the ordering `BENCH_serve.json` records.
#[test]
fn batched_affinity_beats_unbatched_fifo() {
    let corpus = serve::serve_corpus();
    let stream =
        StreamCfg::same_matrix_heavy(SERVE_SEED, harness::serve_requests(), 1500.0, SERVE_HOT_PCT);
    let reqs = serve::gen_stream(&stream, &corpus);
    let fifo = serve::run_serve(&ServeCfg::new(2, 1).policy(Policy::Fifo), &corpus, &reqs)
        .unwrap()
        .summary;
    let best = serve::run_serve(
        &ServeCfg::new(2, 1)
            .policy(Policy::Affinity)
            .batched(SERVE_WINDOW, SERVE_MAX_BATCH),
        &corpus,
        &reqs,
    )
    .unwrap()
    .summary;
    assert!(
        best.p95_latency < fifo.p95_latency,
        "batched affinity p95 {} must beat unbatched FIFO p95 {}",
        best.p95_latency,
        fifo.p95_latency
    );
    assert!(
        best.throughput_nnz > fifo.throughput_nnz,
        "batched affinity throughput {} must beat unbatched FIFO {}",
        best.throughput_nnz,
        fifo.throughput_nnz
    );
    // the mechanism: strictly less simulated time for the same work
    assert!(best.makespan < fifo.makespan);
    assert!(best.batches > 0);
}

/// Render records to JSON lines with the host wall stamps stripped:
/// `wall_ms` / `wall_us_per_request` measure the simulator (not the
/// simulated system) and are the only fields documented to vary run to
/// run — every simulated field must be byte-identical across `--jobs`.
fn sim_lines(mut recs: Vec<Record>) -> Vec<String> {
    recs.iter_mut()
        .map(|r| {
            r.fields.retain(|(k, _)| !k.starts_with("wall"));
            r.to_json_line()
        })
        .collect()
}

/// `BENCH_serve.json` determinism: the same seed produces byte-identical
/// record lines for every `--jobs` (the experiment-engine guarantee,
/// exercised end to end through the serving engine).
#[test]
fn serve_records_are_jobs_invariant() {
    let combos = || {
        vec![
            ServeCombo {
                policy: Policy::Fifo,
                clusters: 2,
                mean_gap: 2000.0,
                window: 0,
                cache: true,
            },
            ServeCombo {
                policy: Policy::Affinity,
                clusters: 2,
                mean_gap: 2000.0,
                window: SERVE_WINDOW,
                cache: true,
            },
            ServeCombo {
                policy: Policy::Sjf,
                clusters: 3,
                mean_gap: 2500.0,
                window: 0,
                cache: false,
            },
        ]
    };
    let lines = |jobs: usize| -> Vec<String> {
        let spec = harness::spec_serve_with(16, combos());
        sim_lines(Runner::new(jobs).run(&spec))
    };
    let serial = lines(1);
    let par = lines(4);
    assert_eq!(serial.len(), 3);
    assert_eq!(serial, par, "BENCH_serve records must not depend on --jobs");
    // and the whole pipeline is deterministic run to run
    assert_eq!(serial, lines(2));
}

// ======================================================================
// chaos scenarios — the adversarial acceptance regressions
// ======================================================================

/// Chaos acceptance (a): under the MMPP `burst` arrival process the
/// batching + cache-affinity configuration still beats unbatched FIFO
/// on p99 latency — compressed bursts deepen the queue, which is
/// exactly where coalescing pays. Pins the `burst` scenario ordering
/// `BENCH_chaos.json` reports, and that the ordering is deterministic
/// run to run.
#[test]
fn burst_batched_affinity_beats_unbatched_fifo_on_p99() {
    let corpus = serve::serve_corpus();
    let scfg = Scenario::Burst.stream(CHAOS_SEED, harness::chaos_requests(), CHAOS_GAP);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    let fifo_cfg = ServeCfg::new(2, 1).policy(Policy::Fifo);
    let fifo = serve::run_serve_stream(&fifo_cfg, &corpus, &stream).unwrap().summary;
    let best = serve::run_serve_stream(
        &ServeCfg::new(2, 1)
            .policy(Policy::Affinity)
            .batched(SERVE_WINDOW, SERVE_MAX_BATCH),
        &corpus,
        &stream,
    )
    .unwrap()
    .summary;
    assert!(
        best.p99_latency < fifo.p99_latency,
        "burst: batched affinity p99 {} must beat unbatched FIFO p99 {}",
        best.p99_latency,
        fifo.p99_latency
    );
    assert!(best.makespan < fifo.makespan);
    assert!(best.batches > 0, "bursts must actually coalesce");
    let again = serve::run_serve_stream(&fifo_cfg, &corpus, &stream).unwrap().summary;
    assert_eq!(fifo.p99_latency, again.p99_latency);
    assert_eq!(fifo.makespan, again.makespan);
}

/// Chaos acceptance (b): under tenant `churn` with the cache enabled,
/// departures replay as cache invalidations — the eviction counters
/// rise (every invalidation is a forced eviction) while the churn-free
/// run of the same requests sees none, and churn changes timing only:
/// every per-request result stays bit-identical. Pinned reservations
/// are byte-level, never entries, so no pinned entry can be evicted by
/// construction — [`operand_cache_matches_shadow_lru_model`] checks
/// that accounting invariant directly.
#[test]
fn churn_invalidations_raise_eviction_counters() {
    let corpus = serve::serve_corpus();
    let scfg = Scenario::Churn.stream(CHAOS_SEED, harness::chaos_requests(), CHAOS_GAP);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    assert!(!stream.churn.is_empty(), "churn scenario must schedule departures");
    let cfg = ServeCfg::new(1, 1); // FIFO, unbatched, cache on
    let churned = serve::run_serve_stream(&cfg, &corpus, &stream).unwrap();
    let steady = serve::run_serve(&cfg, &corpus, &stream.reqs).unwrap();
    let stats = |out: &serve::ServeOutcome| {
        let e: u64 = out.clusters.iter().map(|c| c.cache.evictions).sum();
        let i: u64 = out.clusters.iter().map(|c| c.cache.invalidations).sum();
        (e, i)
    };
    let (churn_ev, churn_inv) = stats(&churned);
    let (steady_ev, steady_inv) = stats(&steady);
    assert!(churn_inv > 0, "departures must invalidate cached images");
    assert_eq!(steady_inv, 0, "no churn events, no invalidations");
    assert!(
        churn_ev >= steady_ev + churn_inv,
        "every invalidation is a forced eviction: {churn_ev} vs {steady_ev} + {churn_inv}"
    );
    assert!(churned.summary.hit_rate <= steady.summary.hit_rate);
    assert!(churned.summary.upload_bytes >= steady.summary.upload_bytes);
    // churn perturbs timing only — results stay bit-identical
    for (a, b) in churned.requests.iter().zip(&steady.requests) {
        assert_eq!(a.id, b.id);
        match (&a.result, &b.result) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len());
                for (p, q) in x.iter().zip(y) {
                    assert_eq!(p.to_bits(), q.to_bits(), "request {} diverged under churn", a.id);
                }
            }
            _ => panic!("request {}: result presence diverged under churn", a.id),
        }
    }
}

/// Chaos acceptance (c): under the `flood` scenario with SLO admission
/// control on, the flood tenant (tenant 0, p99 budget 250k cycles)
/// absorbs every shed while each non-flood tenant's served p99 stays
/// within its own budget. One serialized cluster, batching off, so the
/// flood's backlog actually builds. Deterministic across reruns.
#[test]
fn flood_tenant_absorbs_all_sheds_under_slo() {
    let corpus = serve::serve_corpus();
    let scfg = Scenario::Flood.stream(CHAOS_SEED, 2 * harness::chaos_requests(), CHAOS_GAP);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    let tenants = stream.reqs.iter().map(|r| r.tenant + 1).max().unwrap_or(0);
    let slo = SloCfg::flood_default(tenants);
    let cfg = ServeCfg::new(1, 1).slo(slo.clone());
    let out = serve::run_serve_stream(&cfg, &corpus, &stream).unwrap();
    assert!(out.summary.shed_requests > 0, "the flood must trip admission control");
    assert!(out.summary.slo_violations > 0, "shedding implies served over-budget warmup");
    for r in &out.requests {
        if r.shed {
            assert_eq!(r.tenant, 0, "request {}: only the flood tenant may shed", r.id);
            assert_eq!(r.finish, r.start);
            assert_eq!(r.batch_size, 0);
            assert!(r.result.is_none());
        }
    }
    // every non-flood tenant's end-to-end p99 stays inside its budget
    for t in 1..tenants {
        let mut lats: Vec<u64> = out
            .requests
            .iter()
            .filter(|r| !r.shed && r.tenant == t)
            .map(|r| r.latency)
            .collect();
        if lats.is_empty() {
            continue;
        }
        lats.sort_unstable();
        let p99 = lats[((lats.len() as f64 * 0.99).ceil() as usize).max(1) - 1];
        let budget = slo.budget(t).expect("non-flood tenants carry the default budget");
        assert!(p99 <= budget, "tenant {t}: p99 {p99} exceeds budget {budget}");
    }
    let again = serve::run_serve_stream(&cfg, &corpus, &stream).unwrap();
    assert_eq!(out.requests, again.requests, "flood run must be deterministic");
}

/// Chaos acceptance (d): `closed` mode keeps in-flight work bounded by
/// clients x W at every event, while the same stream served open-loop
/// exceeds that bound (the backlog closed-loop exists to prevent).
/// Released arrivals never move earlier than their open-loop instants.
#[test]
fn closed_loop_keeps_queue_depth_within_clients_times_w() {
    let corpus = serve::serve_corpus();
    let scfg = Scenario::Closed.stream(CHAOS_SEED, harness::chaos_requests(), CHAOS_GAP);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    let (clients, w) = Scenario::Closed.closed_clients().expect("closed scenario sets clients");
    let bound = (clients * w) as u64;
    // one serialized cluster: the open-loop backlog provably builds
    let closed_cfg = ServeCfg::new(1, 1).closed_loop(clients, w);
    let closed = serve::run_serve_stream(&closed_cfg, &corpus, &stream).unwrap();
    assert!(closed.summary.max_in_flight >= 1);
    assert!(
        closed.summary.max_in_flight <= bound,
        "closed loop peaked at {} in-flight, bound is {clients}x{w}",
        closed.summary.max_in_flight
    );
    let open = serve::run_serve_stream(&ServeCfg::new(1, 1), &corpus, &stream).unwrap();
    assert!(
        open.summary.max_in_flight > bound,
        "open loop peaked at only {} — the stream no longer overloads",
        open.summary.max_in_flight
    );
    for (c, o) in closed.requests.iter().zip(&open.requests) {
        assert!(c.arrival >= o.arrival, "request {}: release moved earlier", c.id);
    }
    let again = serve::run_serve_stream(&closed_cfg, &corpus, &stream).unwrap();
    assert_eq!(closed.requests, again.requests, "closed run must be deterministic");
}

/// The `AFFINITY_REORDER_WINDOW` aging guard holds under hot-set
/// rotation and the same-matrix flood: whenever the affinity policy
/// dispatches request `y` while an eligible request `x` is still
/// queued, `y` arrived no more than the reorder window after `x` — a
/// cold tenant is never starved past the bound however hard the hot
/// set dominates. Also checks the guard is load-bearing (some genuine
/// reordering happened).
#[test]
fn affinity_reorder_window_holds_under_rotation_and_flood() {
    let corpus = serve::serve_corpus();
    let mut reordered = 0u64;
    for sc in [Scenario::Rotate, Scenario::Flood] {
        let scfg = sc.stream(CHAOS_SEED, harness::chaos_requests(), CHAOS_GAP);
        let stream = serve::gen_stream_ex(&scfg, &corpus);
        let cfg = ServeCfg::new(1, 1).policy(Policy::Affinity);
        let out = serve::run_serve_stream(&cfg, &corpus, &stream).unwrap();
        for y in &out.requests {
            for x in &out.requests {
                if x.arrival <= y.start && x.start > y.start {
                    assert!(
                        y.arrival <= x.arrival + AFFINITY_REORDER_WINDOW,
                        "{}: dispatching {} (arrival {}) starved {} (arrival {}) past the window",
                        sc.name(),
                        y.id,
                        y.arrival,
                        x.id,
                        x.arrival
                    );
                    if y.arrival > x.arrival {
                        reordered += 1;
                    }
                }
            }
        }
    }
    assert!(reordered > 0, "affinity never reordered — the guard is untested");
}

/// Seeded property test: [`OperandCache`] accounting matches an
/// independent shadow LRU model over thousands of random
/// touch/pin/unpin/invalidate/bypass operations. Conserves bytes
/// (`resident_bytes` equals the shadow's entry sum, resident + pinned
/// never exceeds capacity), agrees on every hit/miss/eviction/
/// invalidation/upload counter and residency query, and pinned
/// reservations are only ever changed by pin/unpin — an invalidation
/// or eviction can never reclaim pinned bytes.
#[test]
fn operand_cache_matches_shadow_lru_model() {
    const CAP: u64 = 10_000;
    struct ShEntry {
        matrix: usize,
        form: Form,
        bytes: u64,
        last_use: u64,
    }
    // evict coldest shadow entries until `need` fits under CAP;
    // last_use ticks are unique, so victim order is unambiguous
    fn evict_lru(entries: &mut Vec<ShEntry>, used: &mut u64, evictions: &mut u64, need: u64) {
        while *used + need > CAP {
            let victim = entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .expect("over-budget shadow cache must hold an entry");
            *used -= entries[victim].bytes;
            entries.swap_remove(victim);
            *evictions += 1;
        }
    }
    fn lcg(state: &mut u64) -> u64 {
        *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *state
    }

    let forms = [Form::Csr, Form::Csf, Form::Pipe];
    let mut cache = OperandCache::new(CAP);
    let (mut entries, mut used, mut pinned, mut tick) = (Vec::<ShEntry>::new(), 0u64, 0u64, 0u64);
    let (mut hits, mut misses, mut evictions, mut invalidations, mut upload) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut pins: Vec<u64> = vec![];
    let mut pins_taken = 0u64;
    let mut state = 0x00C0_FFEE_D15E_A5EDu64;
    for step in 0..4000 {
        let r = lcg(&mut state);
        let op = (r >> 8) % 100;
        let matrix = ((r >> 16) % 8) as usize;
        let form = forms[((r >> 24) % 3) as usize];
        let bytes = 400 + 257 * ((r >> 32) % 9);
        if op < 70 {
            let hit = cache.touch(matrix, form, bytes);
            tick += 1;
            let shadow_hit = match entries.iter_mut().find(|e| e.matrix == matrix && e.form == form)
            {
                Some(e) => {
                    e.last_use = tick;
                    hits += 1;
                    true
                }
                None => {
                    misses += 1;
                    upload += bytes;
                    if bytes + pinned <= CAP {
                        evict_lru(&mut entries, &mut used, &mut evictions, bytes + pinned);
                        used += bytes;
                        entries.push(ShEntry { matrix, form, bytes, last_use: tick });
                    }
                    false
                }
            };
            assert_eq!(hit, shadow_hit, "step {step}: hit/miss diverged");
        } else if op < 80 {
            let b = bytes / 2;
            let ok = cache.pin(b);
            let shadow_ok = pinned + b <= CAP;
            if shadow_ok {
                pinned += b;
                evict_lru(&mut entries, &mut used, &mut evictions, pinned);
                pins.push(b);
                pins_taken += 1;
            }
            assert_eq!(ok, shadow_ok, "step {step}: pin admission diverged");
        } else if op < 88 {
            if let Some(b) = pins.pop() {
                cache.unpin(b);
                pinned -= b;
            }
        } else if op < 96 {
            let freed = cache.invalidate_matrix(matrix);
            let mut sfreed = 0u64;
            let mut dropped = 0u64;
            entries.retain(|e| {
                if e.matrix == matrix {
                    sfreed += e.bytes;
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
            used -= sfreed;
            invalidations += dropped;
            evictions += dropped;
            assert_eq!(freed, sfreed, "step {step}: invalidation freed bytes diverged");
        } else {
            cache.bypass(bytes);
            misses += 1;
            upload += bytes;
        }
        // invariants after every operation
        assert_eq!(cache.resident_bytes(), used, "step {step}: resident bytes drifted");
        let entry_sum: u64 = entries.iter().map(|e| e.bytes).sum();
        assert_eq!(used, entry_sum, "step {step}: shadow byte conservation broke");
        assert_eq!(cache.pinned_bytes(), pinned, "step {step}: pinned bytes drifted");
        assert!(cache.resident_bytes() + cache.pinned_bytes() <= CAP, "step {step}: over cap");
        assert_eq!(cache.stats.hits, hits, "step {step}");
        assert_eq!(cache.stats.misses, misses, "step {step}");
        assert_eq!(cache.stats.evictions, evictions, "step {step}");
        assert_eq!(cache.stats.invalidations, invalidations, "step {step}");
        assert_eq!(cache.stats.upload_bytes, upload, "step {step}");
        for m in 0..8 {
            assert_eq!(
                cache.contains_matrix(m),
                entries.iter().any(|e| e.matrix == m),
                "step {step}: residency of matrix {m} diverged"
            );
        }
    }
    // the op mix must have exercised every path
    assert!(hits > 0 && misses > 0, "degenerate op sequence");
    assert!(invalidations > 0, "no invalidations exercised");
    assert!(evictions > invalidations, "no capacity evictions exercised");
    assert!(pins_taken > 0, "no pins exercised");
}

/// `BENCH_chaos.json` determinism: every simulated field of the chaos
/// records is byte-identical across `--jobs` (each grid point
/// regenerates its scenario stream and serves it in one
/// single-threaded engine run, including the SLO flood and closed-loop
/// points).
#[test]
fn chaos_records_are_jobs_invariant() {
    let combos = || {
        vec![
            ChaosCombo { scenario: Scenario::Burst, policy: Policy::Affinity, cache: true },
            ChaosCombo { scenario: Scenario::Churn, policy: Policy::Fifo, cache: true },
            ChaosCombo { scenario: Scenario::Flood, policy: Policy::Fifo, cache: false },
            ChaosCombo { scenario: Scenario::Closed, policy: Policy::Sjf, cache: true },
        ]
    };
    let lines = |jobs: usize| -> Vec<String> {
        let spec = harness::spec_chaos_with(16, combos());
        sim_lines(Runner::new(jobs).run(&spec))
    };
    let serial = lines(1);
    assert_eq!(serial.len(), 4);
    assert_eq!(serial, lines(4), "BENCH_chaos records must not depend on --jobs");
    assert_eq!(serial, lines(2));
}
