//! Serving-engine integration tests: determinism of the `serve` sweep
//! records across `--jobs`, bit-identity of coalesced `smxdm` batches
//! vs the per-request `smxdv` runs they replace, and the acceptance
//! regression pinning that batching + cache-affinity beats unbatched
//! FIFO on a same-matrix-heavy stream (the ordering `BENCH_serve.json`
//! reports).

use sssr::experiments::Runner;
use sssr::harness::{self, ServeCombo, SERVE_HOT_PCT, SERVE_MAX_BATCH, SERVE_SEED, SERVE_WINDOW};
use sssr::kernels::api::{must_execute, ExecCfg, Operand};
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::serve::{self, batch, Policy, ServeCfg, StreamCfg};

/// Differential: a coalesced `smxdm` batch returns bit-identical
/// columns to the standalone `smxdv` runs it replaces (both variants).
/// This is the contract that lets the serving engine batch without
/// changing any tenant-visible number.
#[test]
fn smxdm_batch_bit_identical_to_smxdv_runs() {
    let m = matgen::random_csr(0xB0, 48, 64, 320);
    let vecs: Vec<Vec<f64>> = (0..4u64).map(|j| matgen::random_dense(0xB1 + j, 64)).collect();
    let cfg = ExecCfg::single_cc();
    for variant in [Variant::Base, Variant::Sssr] {
        let singles: Vec<Vec<f64>> = vecs
            .iter()
            .map(|b| {
                let ops = [Operand::Csr(&m), Operand::Dense(b)];
                must_execute("smxdv", variant, IdxWidth::U16, &ops, &cfg)
                    .output
                    .as_dense()
                    .unwrap()
                    .to_vec()
            })
            .collect();
        let refs: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        let d = batch::interleave(&refs);
        let ops = [Operand::Csr(&m), Operand::Dense(&d), Operand::Scalar(2)];
        let run = must_execute("smxdm", variant, IdxWidth::U16, &ops, &cfg);
        let cols = batch::scatter(run.output.as_dense().unwrap(), m.nrows, 4);
        for (j, (got, want)) in cols.iter().zip(&singles).enumerate() {
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{variant:?}: batch column {j} differs from its smxdv run at row {i}"
                );
            }
        }
    }
}

/// Engine-level differential: serving the same stream with batching on
/// vs off yields bit-identical per-request results — coalescing changes
/// timing only.
#[test]
fn engine_batching_preserves_results_bitwise() {
    let corpus = serve::serve_corpus();
    let stream = StreamCfg::same_matrix_heavy(SERVE_SEED, 32, 1500.0, SERVE_HOT_PCT);
    let reqs = serve::gen_stream(&stream, &corpus);
    let unbatched = serve::run_serve(&ServeCfg::new(2, 1), &corpus, &reqs).unwrap();
    let batched = serve::run_serve(
        &ServeCfg::new(2, 1).batched(SERVE_WINDOW, SERVE_MAX_BATCH),
        &corpus,
        &reqs,
    )
    .unwrap();
    assert!(
        batched.summary.batches > 0,
        "the overloaded hot stream must actually coalesce"
    );
    assert!(batched.summary.batched_requests >= 2 * batched.summary.batches);
    for (a, b) in unbatched.requests.iter().zip(&batched.requests) {
        assert_eq!(a.id, b.id);
        match (&a.result, &b.result) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len(), "request {}", a.id);
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "request {} result diverged at row {i}",
                        a.id
                    );
                }
            }
            _ => panic!("request {}: result presence diverged", a.id),
        }
    }
}

/// Acceptance regression: on the same-matrix-heavy stream, the batching
/// + cache-affinity configuration beats unbatched FIFO on both p95
/// simulated-cycle latency and nnz/cycle throughput. The two
/// configurations are exactly the quick-grid rows
/// `fifo/c2/g1500/w0/cache` and `affinity/c2/g1500/w32000/cache` of
/// `spec_serve`, so this pins the ordering `BENCH_serve.json` records.
#[test]
fn batched_affinity_beats_unbatched_fifo() {
    let corpus = serve::serve_corpus();
    let stream =
        StreamCfg::same_matrix_heavy(SERVE_SEED, harness::serve_requests(), 1500.0, SERVE_HOT_PCT);
    let reqs = serve::gen_stream(&stream, &corpus);
    let fifo = serve::run_serve(&ServeCfg::new(2, 1).policy(Policy::Fifo), &corpus, &reqs)
        .unwrap()
        .summary;
    let best = serve::run_serve(
        &ServeCfg::new(2, 1)
            .policy(Policy::Affinity)
            .batched(SERVE_WINDOW, SERVE_MAX_BATCH),
        &corpus,
        &reqs,
    )
    .unwrap()
    .summary;
    assert!(
        best.p95_latency < fifo.p95_latency,
        "batched affinity p95 {} must beat unbatched FIFO p95 {}",
        best.p95_latency,
        fifo.p95_latency
    );
    assert!(
        best.throughput_nnz > fifo.throughput_nnz,
        "batched affinity throughput {} must beat unbatched FIFO {}",
        best.throughput_nnz,
        fifo.throughput_nnz
    );
    // the mechanism: strictly less simulated time for the same work
    assert!(best.makespan < fifo.makespan);
    assert!(best.batches > 0);
}

/// `BENCH_serve.json` determinism: the same seed produces byte-identical
/// record lines for every `--jobs` (the experiment-engine guarantee,
/// exercised end to end through the serving engine).
#[test]
fn serve_records_are_jobs_invariant() {
    let combos = || {
        vec![
            ServeCombo {
                policy: Policy::Fifo,
                clusters: 2,
                mean_gap: 2000.0,
                window: 0,
                cache: true,
            },
            ServeCombo {
                policy: Policy::Affinity,
                clusters: 2,
                mean_gap: 2000.0,
                window: SERVE_WINDOW,
                cache: true,
            },
            ServeCombo {
                policy: Policy::Sjf,
                clusters: 3,
                mean_gap: 2500.0,
                window: 0,
                cache: false,
            },
        ]
    };
    let lines = |jobs: usize| -> Vec<String> {
        let spec = harness::spec_serve_with(16, combos());
        Runner::new(jobs)
            .run(&spec)
            .iter()
            .map(|r| r.to_json_line())
            .collect()
    };
    let serial = lines(1);
    let par = lines(4);
    assert_eq!(serial.len(), 3);
    assert_eq!(serial, par, "BENCH_serve records must not depend on --jobs");
    // and the whole pipeline is deterministic run to run
    assert_eq!(serial, lines(2));
}
