//! Registry-driven kernel conformance sweep: every registered kernel ×
//! every supported variant × every supported index width runs on
//! randomized sample operands through the single `execute` entry point,
//! and the output is checked against the `formats::ops` oracle in one
//! generic loop. Adding a kernel to the registry automatically enrolls
//! it here — no per-kernel test code.

use sssr::kernels::api::{
    self, borrow_all, check_output, execute, ExecCfg, KernelError, Operand, TargetKind,
};
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::sim::{ClusterCfg, SystemCfg};

#[test]
fn every_kernel_variant_width_conforms_to_its_oracle() {
    for (ki, k) in api::REGISTRY.iter().enumerate() {
        for (wi, &iw) in k.widths().iter().enumerate() {
            for (vi, &v) in k.variants().iter().enumerate() {
                let seed = 0x5EED_0000 + (ki as u64) * 64 + (wi as u64) * 8 + vi as u64;
                let owned = k.sample(seed, iw);
                let ops = borrow_all(&owned);
                let cfg = ExecCfg::single_sized(k.tcdm_default());
                // execute() verifies internally; any mismatch or hang is
                // a typed error here, not a process abort
                let run = execute(*k, v, iw, &ops, &cfg).unwrap_or_else(|e| {
                    panic!("{} [{:?} {:?}]: {e}", k.name(), v, iw);
                });
                assert!(run.report.cycles > 0, "{}: zero-cycle run", k.name());
                // and the generic loop re-checks against the oracle
                check_output(k.name(), &run.output, &k.oracle(&ops)).unwrap_or_else(|e| {
                    panic!("{} [{:?} {:?}] oracle recheck: {e}", k.name(), v, iw);
                });
            }
        }
    }
}

#[test]
fn sharded_kernels_conform_on_cluster_and_system_targets() {
    // the sharded matrix kernels also run on the cluster/system targets;
    // sweep those through the same generic entry point
    let m = matgen::random_csr(77, 120, 256, 2000);
    let b = matgen::random_dense(78, 256);
    let sv = matgen::random_spvec(79, 256, 30);
    let dv_ops = [Operand::Csr(&m), Operand::Dense(&b)];
    let sv_ops = [Operand::Csr(&m), Operand::SpVec(&sv)];
    for (name, ops) in [("smxdv", &dv_ops), ("smxsv", &sv_ops)] {
        let k = api::kernel(name).unwrap();
        assert!(k.targets().contains(&TargetKind::Cluster));
        assert!(k.targets().contains(&TargetKind::System));
        for cfg in [
            ExecCfg::cluster(ClusterCfg::paper_cluster()),
            ExecCfg::system(SystemCfg::paper_system(2, 1)),
        ] {
            let run = execute(k, Variant::Sssr, IdxWidth::U16, ops, &cfg)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            check_output(k.name(), &run.output, &k.oracle(ops))
                .unwrap_or_else(|e| panic!("{name} oracle recheck: {e}"));
        }
    }
    // the two-phase CSF SpGEMM and the triangle count also scale out
    // now; sweep both variants through the same generic entry point
    // (bigger TCDM: the symbolic/numeric passes tile whole fibers)
    let g = matgen::undirected_graph(80, 7, 5);
    let t = sssr::formats::Csf::from_csr(&g);
    let csf_ops = [Operand::Csf(&t), Operand::Csf(&t)];
    let tri_ops = [Operand::Csr(&g)];
    let big = ClusterCfg { tcdm_bytes: 1 << 20, ..ClusterCfg::paper_cluster() };
    for (name, ops) in [("smxsm_csf", &csf_ops[..]), ("tricnt", &tri_ops[..])] {
        let k = api::kernel(name).unwrap();
        assert!(k.targets().contains(&TargetKind::Cluster));
        assert!(k.targets().contains(&TargetKind::System));
        for v in [Variant::Base, Variant::Sssr] {
            for cfg in [
                ExecCfg::cluster(big.clone()),
                ExecCfg::system(SystemCfg { cluster: big.clone(), ..SystemCfg::paper_system(2, 2) }),
            ] {
                let run = execute(k, v, IdxWidth::U16, ops, &cfg)
                    .unwrap_or_else(|e| panic!("{name} [{v:?}]: {e}"));
                check_output(k.name(), &run.output, &k.oracle(ops))
                    .unwrap_or_else(|e| panic!("{name} [{v:?}] oracle recheck: {e}"));
            }
        }
    }
}

#[test]
fn registry_capability_metadata_is_consistent() {
    for k in api::REGISTRY.iter() {
        assert!(!k.name().is_empty());
        assert!(!k.variants().is_empty(), "{} declares no variants", k.name());
        assert!(!k.widths().is_empty(), "{} declares no widths", k.name());
        assert!(
            k.targets().contains(&TargetKind::SingleCc),
            "{} must run on the single-CC target",
            k.name()
        );
        // sample operands must validate for every supported width
        for &iw in k.widths() {
            let owned = k.sample(1, iw);
            let ops = borrow_all(&owned);
            k.validate(&ops, iw)
                .unwrap_or_else(|e| panic!("{} sample invalid: {e}", k.name()));
        }
    }
}

#[test]
fn hang_guard_surfaces_on_every_target() {
    // single-CC
    let a = matgen::random_spvec(5, 512, 128);
    let d = matgen::random_dense(6, 512);
    let ops = [Operand::SpVec(&a), Operand::Dense(&d)];
    let k = api::kernel("svxdv").unwrap();
    match execute(k, Variant::Sssr, IdxWidth::U16, &ops, &ExecCfg::single_cc().with_limit(4)) {
        Err(KernelError::Hang { .. }) => {}
        other => panic!("expected single-CC hang, got {:?}", other.err()),
    }
    // cluster
    let m = matgen::random_csr(7, 64, 128, 600);
    let b = matgen::random_dense(8, 128);
    let ops = [Operand::Csr(&m), Operand::Dense(&b)];
    let k = api::kernel("smxdv").unwrap();
    let cfg = ExecCfg::cluster(ClusterCfg::paper_cluster()).with_limit(4);
    match execute(k, Variant::Sssr, IdxWidth::U16, &ops, &cfg) {
        Err(KernelError::Hang { .. }) => {}
        other => panic!("expected cluster hang, got {:?}", other.err()),
    }
    // system
    let cfg = ExecCfg::system(SystemCfg::paper_system(2, 1)).with_limit(4);
    match execute(k, Variant::Sssr, IdxWidth::U16, &ops, &cfg) {
        Err(KernelError::Hang { .. }) => {}
        other => panic!("expected system hang, got {:?}", other.err()),
    }
}
