//! Tracing-layer property tests.
//!
//! The trace subsystem (`sssr::trace`) is observation-only: arming it
//! must never change a modeled number, and the recorded timelines must
//! be a pure function of the simulated execution — bit-identical with
//! the fast path off and on, and invariant under the parallel system
//! tick's worker count. On top of determinism, the per-phase counter
//! snapshots must satisfy the exact attribution identity
//! (`instret + Σ stalls + barrier + penalty + halted == core_cycles`)
//! and serve request spans must reconcile segment-by-segment with the
//! engine's own outcomes.
//!
//! The trace/fast-path overrides are thread-local and every libtest
//! test runs on its own thread, so tests cannot leak modes into each
//! other; each test still restores the defaults on exit for tidiness.

use sssr::kernels::api::{self, borrow_all, execute, ExecCfg, TargetKind};
use sssr::kernels::multi::run_system_smxdv;
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::serve::{self, Scenario, ServeCfg, SloCfg};
use sssr::sim::fastpath;
use sssr::sim::SystemCfg;
use sssr::trace::{self, chrome, phase, PhaseRow, PhaseTable};

/// Run `f` with tracing armed (recording on + sink armed) and the fast
/// path / worker count forced as given, restoring all defaults
/// afterwards. Both overrides must be set *before* `f` builds any
/// cluster, because components capture the flags at construction.
fn traced<T>(fast: bool, jobs: Option<usize>, f: impl FnOnce() -> T) -> (T, trace::TraceData) {
    trace::set_enabled(Some(true));
    trace::sink_begin();
    fastpath::set_enabled(Some(fast));
    fastpath::set_tick_jobs(jobs);
    let out = f();
    fastpath::set_enabled(None);
    fastpath::set_tick_jobs(None);
    trace::set_enabled(None);
    (out, trace::sink_take().expect("sink was armed"))
}

/// A run's complete observable outcome in exactly-comparable form.
fn fingerprint(run: &api::KernelRun) -> (u64, String, String) {
    (run.report.cycles, format!("{:?}", run.output), format!("{:?}", run.report.stats))
}

/// Shared small system workload (mirrors `tests/sim_fastpath.rs`):
/// 4 nnz-balanced row shards on 2 HBM channels with a shrunken backing
/// store so the test does not allocate 256 MiB.
fn small_system() -> SystemCfg {
    SystemCfg { shard_bytes: 4 << 20, ..SystemCfg::paper_system(4, 2) }
}

/// Property: arming the tracer changes no modeled number. Same kernel,
/// same seed, recording off vs on — identical cycles, outputs, and
/// stats, for both a plain kernel and the two-phase SpGEMM.
#[test]
fn tracing_changes_no_modeled_number() {
    for name in ["smxdv", "smxsm_csf"] {
        let k = api::kernel(name).expect("registry kernel");
        let owned = k.sample(0xFA57, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::single_sized(k.tcdm_default());
        let run = |on: bool| {
            trace::set_enabled(Some(on));
            let r = execute(k, Variant::Sssr, IdxWidth::U16, &ops, &cfg)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            trace::set_enabled(None);
            fingerprint(&r)
        };
        assert_eq!(run(false), run(true), "{name}: tracing perturbed the run");
    }
}

/// Property: for every single-CC registry kernel, the recorded
/// timelines are bit-identical with the fast path off and on, in both
/// BASE and SSSR variants. The quiet-horizon skip can only cover
/// windows without state transitions, so the run-length span recorders
/// must see the exact same label sequence either way.
#[test]
fn single_cc_traces_identical_fastpath_vs_naive() {
    for k in api::REGISTRY.iter() {
        if !k.targets().contains(&TargetKind::SingleCc) {
            continue;
        }
        let owned = k.sample(0xFA57, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::single_sized(k.tcdm_default());
        for v in [Variant::Base, Variant::Sssr] {
            let run = |fast| {
                traced(fast, None, || {
                    execute(*k, v, IdxWidth::U16, &ops, &cfg)
                        .unwrap_or_else(|e| panic!("{} [{v:?}]: {e}", k.name()))
                })
            };
            let (naive_run, naive) = run(false);
            let (fast_run, fast) = run(true);
            assert_eq!(
                fingerprint(&naive_run),
                fingerprint(&fast_run),
                "{} [{v:?}]: fast path changed the run",
                k.name()
            );
            assert!(!naive.tracks.is_empty(), "{} [{v:?}]: no tracks recorded", k.name());
            assert_eq!(
                format!("{:?}", naive.tracks),
                format!("{:?}", fast.tracks),
                "{} [{v:?}]: fast path changed the trace",
                k.name()
            );
            assert_eq!(
                chrome::render(&naive),
                chrome::render(&fast),
                "{} [{v:?}]: rendered trace diverged",
                k.name()
            );
        }
    }
}

/// Property: the multi-cluster system trace (per-cluster component
/// tracks plus the HBM channel burst tracks) is invariant under the
/// fast path and the parallel-tick worker count, byte for byte.
#[test]
fn system_traces_invariant_under_jobs_and_fastpath() {
    let m = matgen::random_csr(0xA11, 96, 160, 2200);
    let b = matgen::random_dense(0xA12, 160);
    let cfg = small_system();
    let run = |fast, jobs| {
        traced(fast, Some(jobs), || run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg))
    };
    let (base_run, baseline) = run(false, 1);
    assert!(
        baseline.tracks.iter().any(|t| t.name.starts_with("hbm/ch")),
        "system trace must include HBM channel tracks"
    );
    assert!(baseline.tracks.iter().any(|t| t.name.starts_with("c1/")));
    let base_doc = chrome::render(&baseline);
    for (fast, jobs) in [(false, 2), (true, 1), (true, 4)] {
        let (sys, data) = run(fast, jobs);
        assert_eq!(
            base_run.report.cycles,
            sys.report.cycles,
            "fast={fast} jobs={jobs}: cycles moved"
        );
        assert_eq!(base_doc, chrome::render(&data), "fast={fast} jobs={jobs}: trace diverged");
    }
}

/// Property: the attribution identity holds exactly for every
/// single-CC registry kernel (both variants) and for the system run —
/// every ticked core-cycle lands in exactly one table column.
#[test]
fn attribution_sums_exactly_everywhere() {
    for k in api::REGISTRY.iter() {
        if !k.targets().contains(&TargetKind::SingleCc) {
            continue;
        }
        let owned = k.sample(0xFA57, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::single_sized(k.tcdm_default());
        for v in [Variant::Base, Variant::Sssr] {
            let run = execute(*k, v, IdxWidth::U16, &ops, &cfg)
                .unwrap_or_else(|e| panic!("{} [{v:?}]: {e}", k.name()));
            let s = run.report.stats;
            assert!(s.core_cycles > 0, "{} [{v:?}]: no core cycles ticked", k.name());
            assert_eq!(
                phase::accounted(&s),
                s.core_cycles,
                "{} [{v:?}]: attribution broke: {s:?}",
                k.name()
            );
        }
    }
    let m = matgen::random_csr(0xA11, 96, 160, 2200);
    let b = matgen::random_dense(0xA12, 160);
    let sys = run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &small_system());
    let s = sys.report.stats;
    assert_eq!(phase::accounted(&s), s.core_cycles, "system attribution broke: {s:?}");
}

/// Property: the two-phase SpGEMM records exactly one symbolic and one
/// numeric phase row, each individually exact, and the two rows sum to
/// the whole run's totals — on the single-CC target and on the system
/// target (where the rows aggregate all clusters).
#[test]
fn two_phase_rows_cover_the_whole_run() {
    let k = api::kernel("smxsm_csf").expect("registry kernel");
    let owned = k.sample(0xFA57, IdxWidth::U16);
    let ops = borrow_all(&owned);
    for cfg in [ExecCfg::single_sized(k.tcdm_default()), ExecCfg::system(small_system())] {
        let (run, data) = traced(true, None, || {
            execute(k, Variant::Sssr, IdxWidth::U16, &ops, &cfg).expect("smxsm_csf")
        });
        let names: Vec<&str> = data.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["symbolic", "numeric"], "phase rows: {names:?}");
        let table = PhaseTable::new(data.phases.clone());
        assert!(table.exact(), "broken attribution row:\n{}", table.render());
        let total = run.report.stats;
        let (sym, num) = (&data.phases[0].stats, &data.phases[1].stats);
        assert_eq!(sym.cycles + num.cycles, total.cycles);
        assert_eq!(sym.core_cycles + num.core_cycles, total.core_cycles);
        assert_eq!(sym.instret + num.instret, total.instret);
        assert_eq!(sym.flops + num.flops, total.flops);
    }
}

/// Property: pipeline DAG steps deposit one exact phase row per
/// executed kernel step when a sink is armed.
#[test]
fn pipeline_steps_record_exact_phase_rows() {
    use sssr::pipeline::{self, PipeCfg};
    let a = pipeline::laplacian1d(64);
    let rhs = matgen::random_dense(0xC6, 64);
    let pipe = pipeline::cg(&a, &rhs, 1e-8, 30);
    let (out, data) = traced(true, None, || {
        pipe.run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16)).expect("cg pipeline")
    });
    assert!(out.steps > 0);
    assert_eq!(data.phases.len(), out.steps, "one phase row per pipeline step");
    assert!(data.phases[0].name.contains('#'), "step rows are labelled step#index");
    let table = PhaseTable::new(data.phases);
    assert!(table.exact(), "pipeline attribution broke:\n{}", table.render());
}

/// Property: serve request spans reconcile with the engine's own
/// outcomes — one span per request, segments summing to the span
/// (`arrival + queue + dispatch + upload + stage + compute == finish`
/// for served requests, zero segments for shed ones), and aggregates
/// matching the summary.
#[test]
fn serve_spans_reconcile_with_outcomes() {
    use sssr::harness::{self, CHAOS_GAP, CHAOS_SEED};
    let corpus = serve::serve_corpus();
    // Mirror the chaos-suite flood point: one serialized cluster so the
    // flood's backlog builds and admission control actually sheds.
    let scfg = Scenario::Flood.stream(CHAOS_SEED, 2 * harness::chaos_requests(), CHAOS_GAP);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    let tenants = stream.reqs.iter().map(|r| r.tenant + 1).max().unwrap_or(0);
    let cfg = ServeCfg::new(1, 1).slo(SloCfg::flood_default(tenants));
    trace::sink_begin();
    let out = serve::run_serve_stream(&cfg, &corpus, &stream).expect("serve run");
    let data = trace::sink_take().expect("sink was armed");
    assert!(data.tracks.is_empty(), "sink-only arming must not record component tracks");
    assert_eq!(data.serve.len(), out.requests.len(), "one span per request");
    assert!(out.summary.shed_requests > 0, "flood under SLO must shed");

    let mut shed_spans = 0u64;
    for o in &out.requests {
        let sp = data
            .serve
            .iter()
            .find(|s| s.id == o.id as u64)
            .unwrap_or_else(|| panic!("request {} has no span", o.id));
        assert_eq!(sp.arrival, o.arrival);
        assert_eq!(sp.finish, o.finish);
        assert_eq!(sp.queue_cycles, o.queue_cycles);
        assert_eq!(sp.shed, o.shed);
        assert_eq!(sp.cluster, o.cluster);
        assert_eq!(sp.finish - sp.arrival, o.latency, "span {} latency", sp.id);
        if sp.shed {
            shed_spans += 1;
            assert_eq!(sp.batch_size, 0);
            assert_eq!(sp.dispatch_cycles, 0);
            assert_eq!(sp.upload_cycles + sp.stage_cycles + sp.compute_cycles, 0);
            assert_eq!(sp.finish, sp.start, "shed spans end at the shed instant");
        } else {
            assert!(sp.batch_size >= 1);
            let segments = sp.queue_cycles
                + sp.dispatch_cycles
                + sp.upload_cycles
                + sp.stage_cycles
                + sp.compute_cycles;
            assert_eq!(
                sp.arrival + segments,
                sp.finish,
                "span {} segments do not tile the request",
                sp.id
            );
        }
    }
    assert_eq!(shed_spans, out.summary.shed_requests);
    let last = data.serve.iter().map(|s| s.finish).max().unwrap_or(0);
    assert_eq!(last.max(1), out.summary.makespan);
}

/// Property: every trace document this layer produces passes its own
/// validator, and `METRICS_serve.jsonl` carries one record per span.
#[test]
fn chrome_documents_validate_and_metrics_lines_match() {
    // Component + phase trace from a kernel run...
    let k = api::kernel("smxdv").expect("registry kernel");
    let owned = k.sample(0xFA57, IdxWidth::U16);
    let ops = borrow_all(&owned);
    let cfg = ExecCfg::single_sized(k.tcdm_default());
    let (run, mut data) = traced(true, None, || {
        execute(k, Variant::Sssr, IdxWidth::U16, &ops, &cfg).expect("smxdv")
    });
    // ...plus serve spans from an engine run, merged into one document.
    let corpus = serve::serve_corpus();
    let scfg = Scenario::Burst.stream(0x5E12, 40, 900.0);
    let stream = serve::gen_stream_ex(&scfg, &corpus);
    trace::sink_begin();
    serve::run_serve_stream(&ServeCfg::new(2, 1), &corpus, &stream).expect("serve run");
    let sdata = trace::sink_take().expect("sink was armed");
    data.serve = sdata.serve;

    let doc = chrome::render(&data);
    let spans = chrome::check(&doc).unwrap_or_else(|e| panic!("invalid trace: {e}"));
    assert!(spans > 0);
    let jsonl = chrome::metrics_jsonl(&data.serve);
    assert_eq!(jsonl.lines().count(), data.serve.len());

    // The attribution table `repro trace` prints (recorded phases plus
    // a synthesized run-total row) renders exact.
    assert!(data.tracks.iter().any(|t| !t.events.is_empty()), "kernel run recorded no spans");
    data.phases.push(PhaseRow { name: "total".into(), stats: run.report.stats });
    let table = PhaseTable::new(data.phases);
    assert!(table.exact(), "attribution broke:\n{}", table.render());
    assert!(table.render().contains("(exact)"));
}
