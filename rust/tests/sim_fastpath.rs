//! Fast-path equivalence property tests.
//!
//! The simulator's performance fast path (idle fast-forward in the
//! cluster/system run loops plus the parallel channel-group system
//! tick, `sim::fastpath`) is a pure wall-clock optimization: it must
//! never change a modeled number. These tests pin that contract by
//! running the same seed-fixed workloads with the fast path disabled
//! (the naive tick-every-cycle loops) and enabled, and demanding
//! bit-identical outputs, cycle counts, and aggregated run statistics —
//! including `--jobs`-invariance of the parallel system tick and the
//! hang-limit (`Err`) path.
//!
//! The overrides are thread-local and every libtest test runs on its
//! own thread, so tests cannot leak modes into each other; each test
//! still restores the defaults on exit for tidiness.

use sssr::kernels::api::{self, borrow_all, execute, ExecCfg, TargetKind};
use sssr::kernels::multi::run_system_smxdv;
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::sim::asm::Asm;
use sssr::sim::fastpath;
use sssr::sim::isa::{Program, T0, ZERO};
use sssr::sim::{Cluster, ClusterCfg, SystemCfg};

/// Run `f` with the fast path forced to `fast` (and, when given, the
/// system tick worker count forced to `jobs`), restoring the defaults
/// afterwards. The overrides must be set *before* `f` builds any
/// `Cluster`/`System`, because clusters capture the fast-path flag at
/// construction — which is exactly what this helper guarantees.
fn with_mode<T>(fast: bool, jobs: Option<usize>, f: impl FnOnce() -> T) -> T {
    fastpath::set_enabled(Some(fast));
    fastpath::set_tick_jobs(jobs);
    let out = f();
    fastpath::set_enabled(None);
    fastpath::set_tick_jobs(None);
    out
}

/// A run's complete observable outcome, in exactly-comparable form
/// (`f64`s as bit patterns via the `Debug` rendering of the output
/// value; `RunStats` via its `Debug` rendering, which covers every
/// counter field).
fn fingerprint(run: &api::KernelRun) -> (u64, String, String) {
    (run.report.cycles, format!("{:?}", run.output), format!("{:?}", run.report.stats))
}

/// Property: for every registry kernel that runs on the single-CC
/// target, BASE and SSSR at 16-bit indices produce identical cycles,
/// outputs, and stats with the fast path off and on.
#[test]
fn single_cc_registry_equivalence() {
    for k in api::REGISTRY.iter() {
        if !k.targets().contains(&TargetKind::SingleCc) {
            continue;
        }
        let owned = k.sample(0xFA57, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::single_sized(k.tcdm_default());
        for v in [Variant::Base, Variant::Sssr] {
            let run = |fast| {
                with_mode(fast, None, || {
                    execute(*k, v, IdxWidth::U16, &ops, &cfg)
                        .unwrap_or_else(|e| panic!("{} [{v:?}]: {e}", k.name()))
                })
            };
            let naive = fingerprint(&run(false));
            let fast = fingerprint(&run(true));
            assert_eq!(naive, fast, "{} [{v:?}]: fast path changed the run", k.name());
        }
    }
}

/// Shared small system workload: 4 nnz-balanced row shards on 2 HBM
/// channels. `shard_bytes` is shrunk from the 64 MiB paper default so
/// the test does not allocate a 256 MiB backing store.
fn small_system() -> SystemCfg {
    SystemCfg { shard_bytes: 4 << 20, ..SystemCfg::paper_system(4, 2) }
}

/// Property: the multi-cluster system run is invariant under the fast
/// path AND under the parallel-tick worker count (`SIM_TICK_JOBS`):
/// every mode reproduces the sequential naive run bit-identically,
/// per shard.
#[test]
fn system_jobs_invariance() {
    let m = matgen::random_csr(0xA11, 96, 160, 2200);
    let b = matgen::random_dense(0xA12, 160);
    let cfg = small_system();
    let run = |fast, jobs| {
        with_mode(fast, Some(jobs), || run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg))
    };
    let baseline = run(false, 1);
    let base_bits: Vec<u64> = baseline.result.iter().map(|x| x.to_bits()).collect();
    for (fast, jobs) in [(false, 2), (true, 1), (true, 2), (true, 8)] {
        let sys = run(fast, jobs);
        let bits: Vec<u64> = sys.result.iter().map(|x| x.to_bits()).collect();
        assert_eq!(base_bits, bits, "fast={fast} jobs={jobs}: result diverged");
        assert_eq!(baseline.report.cycles, sys.report.cycles, "fast={fast} jobs={jobs}");
        assert_eq!(
            format!("{:?}", baseline.report.stats),
            format!("{:?}", sys.report.stats),
            "fast={fast} jobs={jobs}: aggregate stats diverged"
        );
        for (a, z) in baseline.shards.iter().zip(&sys.shards) {
            assert_eq!(a.rows, z.rows);
            assert_eq!(a.cycles, z.cycles, "fast={fast} jobs={jobs}: shard finish time moved");
            assert_eq!(format!("{:?}", a.hbm), format!("{:?}", z.hbm));
        }
    }
}

/// Regression for the system-layer lockstep inefficiency: one giant
/// row pins cluster 0 while the other shard's clusters finish almost
/// immediately and idle. The early-finishing clusters must not change
/// any modeled number when the surviving cluster is fast-forwarded
/// past them — and the skew itself must be visible in the per-shard
/// finish times.
#[test]
fn skewed_shard_equivalence() {
    // Row 0 is fully dense and carries nearly all nonzeros; contiguous
    // nnz-balanced sharding cannot split a row, so it isolates row 0 on
    // cluster 0 while cluster 1 drains its 63 single-nonzero rows
    // quickly and then idles.
    let ncols = 2048usize;
    let heavy = ncols;
    let nrows = 64usize;
    let mut ptrs = vec![0u32; nrows + 1];
    let mut idcs = Vec::new();
    let mut vals = Vec::new();
    for j in 0..heavy {
        idcs.push(j as u32);
        vals.push(1.0 + j as f64 * 0.5);
    }
    ptrs[1] = heavy as u32;
    for r in 1..nrows {
        idcs.push((r % ncols) as u32);
        vals.push(r as f64);
        ptrs[r + 1] = ptrs[r] + 1;
    }
    let m = sssr::formats::Csr::new(nrows, ncols, ptrs, idcs, vals);
    let b = matgen::random_dense(0xBEEF, ncols);
    let cfg = SystemCfg { shard_bytes: 4 << 20, ..SystemCfg::paper_system(2, 2) };
    let run = |fast, jobs| {
        with_mode(fast, Some(jobs), || run_system_smxdv(Variant::Sssr, IdxWidth::U16, &m, &b, &cfg))
    };
    let naive = run(false, 1);
    assert!(
        naive.shards[1].cycles < naive.shards[0].cycles,
        "workload is not skewed: {} !< {}",
        naive.shards[1].cycles,
        naive.shards[0].cycles
    );
    for (fast, jobs) in [(true, 1), (true, 2)] {
        let sys = run(fast, jobs);
        assert_eq!(naive.report.cycles, sys.report.cycles, "fast={fast} jobs={jobs}");
        let bits = |s: &sssr::kernels::multi::SystemRun| {
            s.result.iter().map(|x| x.to_bits()).collect::<Vec<u64>>()
        };
        assert_eq!(bits(&naive), bits(&sys), "fast={fast} jobs={jobs}");
        for (a, z) in naive.shards.iter().zip(&sys.shards) {
            assert_eq!(a.cycles, z.cycles, "fast={fast} jobs={jobs}: shard finish time moved");
        }
    }
}

/// A deadlocked cluster (core 0 waits at a barrier core 1 never
/// reaches) exercises the `u64::MAX` idle horizon: the fast path must
/// report the exact same hang — same `Err(limit)`, same final cycle,
/// same stall accounting — as ticking every cycle to the cap.
#[test]
fn hang_limit_err_equivalence() {
    let deadlock_progs = || -> Vec<Program> {
        let mut a = Asm::new();
        a.barrier();
        a.halt();
        let waiter = a.finish();
        let mut b = Asm::new();
        b.li(T0, 7);
        b.add(T0, T0, ZERO);
        b.halt();
        let quitter = b.finish();
        vec![waiter, quitter]
    };
    let cfg = ClusterCfg { cores: 2, ..ClusterCfg::paper_cluster() };
    let limit = 5_000u64;
    let run = |fast| {
        with_mode(fast, None, || {
            let mut cl = Cluster::new(cfg.clone(), deadlock_progs());
            let r = cl.try_run_isolated(limit);
            (r, cl.cycle, format!("{:?}", cl.stats()))
        })
    };
    let naive = run(false);
    let fast = run(true);
    assert_eq!(naive.0, Err(limit));
    assert_eq!(naive, fast, "fast path changed the hang-limit outcome");
}
