//! Kernel-DAG pipeline end-to-end tests: the four iterative
//! applications ([`sssr::pipeline::apps`]) checked against dense host
//! oracles, plus the PR's acceptance pin — HBM-resident intermediates
//! move strictly fewer host↔HBM bytes than per-step round-tripping
//! while producing bit-identical outputs.

use sssr::formats::Csr;
use sssr::kernels::apps::Stencil1d;
use sssr::kernels::{IdxWidth, Variant};
use sssr::matgen;
use sssr::pipeline::{self, PipeCfg, PipeRun, Val};

/// Pull one named output buffer's dense value out of a run.
fn dense_output<'a>(run: &'a PipeRun, name: &str) -> &'a [f64] {
    let (_, v) = run
        .outputs
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("no output buffer {name:?}"));
    match v {
        Val::Dense(d) => d,
        other => panic!("output {name:?} is not dense: {other:?}"),
    }
}

/// Dense Gaussian elimination with partial pivoting — the oracle the
/// pipeline CG solve is checked against.
fn dense_solve(a: &Csr, b: &[f64]) -> Vec<f64> {
    let n = a.nrows;
    let mut m = a.to_dense();
    let mut x = b.to_vec();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
            .unwrap();
        m.swap(col, piv);
        x.swap(col, piv);
        assert!(m[col][col].abs() > 1e-12, "singular oracle system");
        for row in col + 1..n {
            let f = m[row][col] / m[col][col];
            for k in col..n {
                m[row][k] -= f * m[col][k];
            }
            x[row] -= f * x[col];
        }
    }
    for col in (0..n).rev() {
        x[col] /= m[col][col];
        for row in 0..col {
            x[row] -= m[row][col] * x[col];
        }
    }
    x
}

#[test]
fn pagerank_stays_stochastic_and_matches_power_iteration() {
    let p = pipeline::column_stochastic(&matgen::mycielskian(6));
    let pipe = pipeline::pagerank(&p, 0.85, 0, 1e-6, 40);
    let run = pipe
        .run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16))
        .expect("pagerank pipeline");
    let x = dense_output(&run, "x");

    // Column-stochastic operator + personalized teleport conserve
    // probability mass: the rank vector stays a distribution.
    let sum: f64 = x.iter().sum();
    assert!((sum - 1.0).abs() < 1e-9, "rank mass drifted: sum = {sum}");
    assert!(x.iter().all(|&v| v >= -1e-12), "negative rank entry");

    // And the sparse-frontier pipeline tracks the dense power-iteration
    // oracle entrywise (same damping, seed, tolerance, iteration cap).
    let oracle = pipeline::pagerank_reference(&p, 0.85, 0, 1e-6, 40);
    assert_eq!(x.len(), oracle.len());
    for (i, (&got, &want)) in x.iter().zip(&oracle).enumerate() {
        assert!((got - want).abs() < 1e-6, "rank[{i}]: pipeline {got} vs oracle {want}");
    }
}

#[test]
fn cg_residuals_non_increasing_and_solution_matches_dense_solve() {
    let a = pipeline::laplacian1d(96);
    let rhs = matgen::random_dense(0xC6, 96);
    let pipe = pipeline::cg(&a, &rhs, 1e-12, 200);
    let run = pipe
        .run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16))
        .expect("cg pipeline");

    // ‖r‖ trajectory: monotonically non-increasing on this
    // well-conditioned SPD system, and converged below the tolerance.
    assert!(run.residuals.len() >= 2, "CG converged suspiciously fast");
    for w in run.residuals.windows(2) {
        assert!(
            w[1] <= w[0] * (1.0 + 1e-9),
            "residual increased: {} -> {}",
            w[0],
            w[1]
        );
    }
    let last = *run.residuals.last().unwrap();
    assert!(last <= 1e-12, "CG did not converge: final ‖r‖ = {last}");

    // The converged iterate matches the dense direct solve.
    let x = dense_output(&run, "x");
    let oracle = dense_solve(&a, &rhs);
    for (i, (&got, &want)) in x.iter().zip(&oracle).enumerate() {
        assert!((got - want).abs() < 1e-5, "x[{i}]: CG {got} vs direct {want}");
    }
}

#[test]
fn stencil_pipeline_matches_repeated_host_reference() {
    let st = Stencil1d::three_point();
    let grid = matgen::random_dense(0x57, 256);
    let steps = 5;
    let run = pipeline::stencil_steps(&st, &grid, steps)
        .run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16))
        .expect("stencil pipeline");
    let mut want = grid;
    for _ in 0..steps {
        want = st.reference(&want);
    }
    let got = dense_output(&run, "u");
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-12, "u[{i}]: pipeline {g} vs host {w}");
    }
    assert_eq!(run.iters, steps);
}

/// The PR's acceptance pin: on the CG and GNN pipelines, HBM-resident
/// intermediates move strictly fewer host↔HBM bytes than per-step
/// round-tripping, with bit-identical outputs and identical modeled
/// compute cycles (residency only changes where bytes move).
#[test]
fn resident_intermediates_cut_host_bytes_bit_identically() {
    let a = pipeline::column_stochastic(&matgen::mycielskian(6));
    let n = a.nrows;
    let feats = matgen::random_dense(0xF0, n * 8);
    let bias = matgen::random_dense(0xB1, n * 8);
    let gnn = pipeline::gnn_layer(&a, &feats, 3, 0.5, 0.5, &bias);

    let spd = pipeline::laplacian1d(128);
    let rhs = matgen::random_dense(0xC6, 128);
    let cg = pipeline::cg(&spd, &rhs, 1e-10, 100);

    for (name, pipe) in [("gnn", &gnn), ("cg", &cg)] {
        let cfg = PipeCfg::new(Variant::Sssr, IdxWidth::U16);
        let res = pipe.run(&cfg).unwrap_or_else(|e| panic!("{name} resident: {e}"));
        let rt = pipe
            .run(&cfg.clone().roundtrip())
            .unwrap_or_else(|e| panic!("{name} roundtrip: {e}"));
        assert_eq!(res.outputs, rt.outputs, "{name}: outputs diverged across residency modes");
        assert_eq!(res.cycles, rt.cycles, "{name}: compute cycles depend on residency");
        assert!(
            res.host_bytes < rt.host_bytes,
            "{name}: residency saved nothing ({} vs {} host bytes)",
            res.host_bytes,
            rt.host_bytes
        );
    }

    // The iterative solve round-trips every per-iteration intermediate,
    // so residency must save a large factor there, not a rounding error.
    let res = cg.run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16)).unwrap();
    let rt = cg
        .run(&PipeCfg::new(Variant::Sssr, IdxWidth::U16).roundtrip())
        .unwrap();
    assert!(
        res.host_bytes * 2 <= rt.host_bytes,
        "CG residency should at least halve host traffic ({} vs {})",
        res.host_bytes,
        rt.host_bytes
    );
}
