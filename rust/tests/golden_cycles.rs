//! Golden cycle-count regression: one representative workload per
//! registry kernel, BASE and SSSR cycle counts pinned in a snapshot
//! file. Any change that shifts simulated timing — streamer arbitration,
//! FREP issue, TCDM banking, kernel scheduling — fails here loudly
//! instead of silently moving every figure.
//!
//! The simulator is pure and the workloads are seed-fixed, so the
//! counts are exact and machine-invariant. On first run (no snapshot
//! yet) the test records `tests/golden_cycles.snap` and passes; COMMIT
//! that file to arm the guard — until it is committed, a fresh checkout
//! self-records and the pin is inert. After an *intentional* timing
//! change, regenerate with `GOLDEN_BLESS=1 cargo test --test
//! golden_cycles` and commit the diff alongside the change that caused
//! it.

use std::path::PathBuf;

use sssr::kernels::api::{self, borrow_all, execute, ExecCfg, TargetKind};
use sssr::kernels::{IdxWidth, Variant};
use sssr::sim::{ClusterCfg, SystemCfg};

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden_cycles.snap")
}

/// Fixed-seed representative run of every registry kernel: 16-bit
/// indices (supported everywhere), BASE and SSSR variants (ditto), the
/// kernel's own sample workload. Kernels carrying the System target row
/// are additionally pinned on a 2-cluster system (`name@sys2`), so the
/// scale-out paths — sharding, DMA phasing, barrier protocol, CSF
/// gather — are cycle-guarded like the single-CC bodies.
fn measure() -> Vec<(String, u64, u64)> {
    let single = |k: &&'static dyn api::Kernel| {
        let owned = k.sample(0x601D, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::single_sized(k.tcdm_default());
        let mut cycles = [0u64; 2];
        for (i, v) in [Variant::Base, Variant::Sssr].into_iter().enumerate() {
            let run = execute(*k, v, IdxWidth::U16, &ops, &cfg)
                .unwrap_or_else(|e| panic!("{} [{v:?}]: {e}", k.name()));
            cycles[i] = run.report.cycles;
        }
        (k.name().to_string(), cycles[0], cycles[1])
    };
    let mut rows: Vec<(String, u64, u64)> = api::REGISTRY.iter().map(single).collect();
    for k in api::REGISTRY.iter().filter(|k| k.targets().contains(&TargetKind::System)) {
        let owned = k.sample(0x601D, IdxWidth::U16);
        let ops = borrow_all(&owned);
        let cfg = ExecCfg::system(SystemCfg {
            cluster: ClusterCfg { tcdm_bytes: 1 << 20, ..ClusterCfg::paper_cluster() },
            ..SystemCfg::paper_system(2, 2)
        });
        let mut cycles = [0u64; 2];
        for (i, v) in [Variant::Base, Variant::Sssr].into_iter().enumerate() {
            let run = execute(*k, v, IdxWidth::U16, &ops, &cfg)
                .unwrap_or_else(|e| panic!("{}@sys2 [{v:?}]: {e}", k.name()));
            cycles[i] = run.report.cycles;
        }
        rows.push((format!("{}@sys2", k.name()), cycles[0], cycles[1]));
    }
    rows
}

fn render(rows: &[(String, u64, u64)]) -> String {
    let mut s = String::from("# kernel base_cycles sssr_cycles (seed 0x601D, 16-bit)\n");
    for (name, base, sssr) in rows {
        s.push_str(&format!("{name} {base} {sssr}\n"));
    }
    s
}

#[test]
fn golden_cycle_counts_are_pinned() {
    let rows = measure();
    let rendered = render(&rows);
    let path = snapshot_path();
    let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
    // CI sets GOLDEN_REQUIRE=1: there a missing snapshot is a loud
    // failure, not a silent self-record — an unarmed guard on a fresh
    // checkout means the snapshot was never committed.
    let require = std::env::var("GOLDEN_REQUIRE").map(|v| v == "1").unwrap_or(false);
    let pinned = std::fs::read_to_string(&path).ok();
    if pinned.is_none() && require && !bless {
        panic!(
            "golden snapshot {} is missing but GOLDEN_REQUIRE=1 (CI): the \
             cycle-count guard is unarmed. Run `cargo test -q` locally and \
             commit the self-recorded rust/tests/golden_cycles.snap.",
            path.display()
        );
    }
    match pinned {
        Some(pinned) if !bless => {
            if pinned == rendered {
                return;
            }
            // report every drifted kernel, not just the first
            let old: Vec<&str> = pinned.lines().collect();
            let new: Vec<&str> = rendered.lines().collect();
            let mut drift = String::new();
            for line in &new {
                if !old.contains(line) {
                    drift.push_str(&format!("  now:    {line}\n"));
                }
            }
            for line in &old {
                if !new.contains(line) {
                    drift.push_str(&format!("  pinned: {line}\n"));
                }
            }
            panic!(
                "golden cycle counts drifted from {}:\n{drift}\
                 If this change is intentional, regenerate with \
                 `GOLDEN_BLESS=1 cargo test --test golden_cycles` and \
                 commit the updated snapshot.",
                path.display()
            );
        }
        _ => {
            std::fs::write(&path, &rendered)
                .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
            eprintln!(
                "golden_cycles: recorded snapshot at {} — commit it to pin",
                path.display()
            );
        }
    }
}

#[test]
fn golden_workloads_cover_every_registry_kernel() {
    // the snapshot keys are exactly the registry names, in order — a
    // new kernel cannot land without entering the golden set
    let rows = measure();
    let names: Vec<&str> = rows.iter().map(|(n, _, _)| n.as_str()).collect();
    let registry: Vec<&str> = api::REGISTRY.iter().map(|k| k.name()).collect();
    assert_eq!(&names[..registry.len()], &registry[..]);
    // ...followed by one @sys2 pin per System-capable kernel
    let sys: Vec<String> = api::REGISTRY
        .iter()
        .filter(|k| k.targets().contains(&TargetKind::System))
        .map(|k| format!("{}@sys2", k.name()))
        .collect();
    assert_eq!(&names[registry.len()..], &sys[..]);
    assert!(sys.iter().any(|n| n == "smxsm_csf@sys2"));
    assert!(sys.iter().any(|n| n == "tricnt@sys2"));
    // loose sanity only — the exact values are the snapshot's job; the
    // samples are small, so BASE-vs-SSSR ratios are not asserted here
    for (name, base, sssr) in rows {
        assert!(base > 0 && sssr > 0, "{name}: zero-cycle run");
    }
}
