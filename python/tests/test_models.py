"""Layer-2 correctness: model entry points vs references, and the AOT
lowering path itself (every artifact must lower to parseable HLO text)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import functools

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def test_pagerank_step_matches_ref():
    rng = np.random.default_rng(1)
    n, k = 16, 4
    vals = rng.random((n, k))
    idcs = rng.integers(0, n, size=(n, k)).astype(np.float64)
    rank = rng.random(n)
    damping = np.array([0.85])
    (got,) = model.pagerank_step_model(
        jnp.array(vals), jnp.array(idcs), jnp.array(rank), jnp.array(damping)
    )
    want = ref.pagerank_step_ref(
        jnp.array(vals), jnp.array(idcs).astype(jnp.int32), jnp.array(rank), 0.85, n
    )
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_pagerank_steps_preserve_mass_on_stochastic_matrix():
    # column-normalized ring graph: total rank stays 1 under iteration
    n = 32
    vals = np.zeros((n, 2))
    idcs = np.zeros((n, 2))
    for i in range(n):
        # node i receives from i-1 and i+1; each sender has out-degree 2
        vals[i] = [0.5, 0.5]
        idcs[i] = [(i - 1) % n, (i + 1) % n]
    rank = jnp.full((n,), 1.0 / n)
    for _ in range(10):
        (rank,) = model.pagerank_step_model(
            jnp.array(vals), jnp.array(idcs), rank, jnp.array([0.85])
        )
    np.testing.assert_allclose(float(jnp.sum(rank)), 1.0, rtol=1e-9)


def test_jacobi_step_reduces_residual():
    rng = np.random.default_rng(2)
    n = 16
    # diagonally dominant tridiagonal system in ELL form
    k = 3
    vals = np.zeros((n, k))
    idcs = np.zeros((n, k))
    dense = np.zeros((n, n))
    for i in range(n):
        entries = [(i, 4.0)]
        if i > 0:
            entries.append((i - 1, -1.0))
        if i + 1 < n:
            entries.append((i + 1, -1.0))
        for j, (c, v) in enumerate(entries):
            idcs[i, j] = c
            vals[i, j] = v
            dense[i, c] = v
    b = rng.standard_normal(n)
    diag_inv = np.full(n, 1.0 / 4.0)
    x = jnp.zeros(n)
    res0 = np.linalg.norm(b - dense @ np.asarray(x))
    for _ in range(20):
        (x,) = model.jacobi_step_model(
            jnp.array(vals), jnp.array(idcs), jnp.array(diag_inv), jnp.array(b), x
        )
    res = np.linalg.norm(b - dense @ np.asarray(x))
    assert res < 1e-6 * max(res0, 1.0), f"Jacobi did not converge: {res0} -> {res}"


def test_all_artifacts_lower_to_hlo_text():
    for name, fn, example, n_outputs in aot.entries():
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, f"{name}: no ENTRY in HLO text"
        assert len(text) > 200, f"{name}: suspiciously small HLO"
        assert n_outputs >= 1


def test_artifact_shapes_consistent_with_models():
    # executing each entry on zeros must produce n_outputs outputs of the
    # declared shape discipline
    for name, fn, example, n_outputs in aot.entries():
        args = [jnp.zeros(s.shape, s.dtype) for s in example]
        out = fn(*args)
        assert len(out) == n_outputs, name
