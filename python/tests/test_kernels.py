"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, densities, and index patterns; explicit cases
cover the edges (empty fibers, full density, duplicate-free padding).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import intersect, ref, spmv, union_add  # noqa: E402


def random_fiber(rng, dim, k, nnz):
    """Padded fiber: `nnz` real entries (distinct sorted indices), rest
    padding (idx 0, val 0)."""
    vals = np.zeros(k, dtype=np.float64)
    idcs = np.zeros(k, dtype=np.int32)
    if nnz:
        pos = np.sort(rng.choice(dim, size=nnz, replace=False)).astype(np.int32)
        idcs[:nnz] = pos
        vals[:nnz] = rng.standard_normal(nnz)
    return vals, idcs


fiber_params = st.tuples(
    st.integers(min_value=1, max_value=200),  # dim
    st.integers(min_value=1, max_value=64),  # k (padded length)
    st.integers(min_value=0, max_value=10_000),  # seed
)


class TestSvxdv:
    @settings(max_examples=40, deadline=None)
    @given(fiber_params)
    def test_matches_ref(self, p):
        dim, k, seed = p
        rng = np.random.default_rng(seed)
        nnz = int(rng.integers(0, min(dim, k) + 1))
        vals, idcs = random_fiber(rng, dim, k, nnz)
        b = rng.standard_normal(dim)
        got = spmv.svxdv(jnp.array(vals), jnp.array(idcs), jnp.array(b))
        want = ref.svxdv_ref(jnp.array(vals), jnp.array(idcs), jnp.array(b))
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_all_padding_is_zero(self):
        vals = jnp.zeros(8)
        idcs = jnp.zeros(8, jnp.int32)
        b = jnp.arange(16, dtype=jnp.float64)
        assert float(spmv.svxdv(vals, idcs, b)) == 0.0


class TestSpmvEll:
    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(min_value=1, max_value=8),  # row blocks
        st.integers(min_value=1, max_value=16),  # k
        st.integers(min_value=0, max_value=10_000),
    )
    def test_matches_ref(self, blocks, k, seed):
        rng = np.random.default_rng(seed)
        block_rows = 4
        n_rows = blocks * block_rows
        n_cols = int(rng.integers(8, 64))
        vals = np.zeros((n_rows, k))
        idcs = np.zeros((n_rows, k), dtype=np.int32)
        for r in range(n_rows):
            w = int(rng.integers(0, k + 1))
            if w:
                idcs[r, :w] = np.sort(rng.choice(n_cols, size=min(w, n_cols), replace=False))[: w]
                vals[r, : min(w, n_cols)] = rng.standard_normal(min(w, n_cols))
        b = rng.standard_normal(n_cols)
        got = spmv.spmv_ell(jnp.array(vals), jnp.array(idcs), jnp.array(b), block_rows=block_rows)
        want = ref.spmv_ell_ref(jnp.array(vals), jnp.array(idcs), jnp.array(b))
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_dtype_f32(self):
        rng = np.random.default_rng(0)
        vals = rng.standard_normal((8, 4)).astype(np.float32)
        idcs = rng.integers(0, 16, size=(8, 4)).astype(np.int32)
        b = rng.standard_normal(16).astype(np.float32)
        got = spmv.spmv_ell(jnp.array(vals), jnp.array(idcs), jnp.array(b))
        want = ref.spmv_ell_ref(jnp.array(vals), jnp.array(idcs), jnp.array(b))
        np.testing.assert_allclose(got, want, rtol=1e-5)
        assert got.dtype == jnp.float32

    def test_ell_from_csr_roundtrip(self):
        ptrs = np.array([0, 2, 2, 5])
        idcs = np.array([1, 3, 0, 2, 4])
        vals = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
        ev, ei = spmv.ell_from_csr(ptrs, idcs, vals, pad_rows_to=4)
        assert ev.shape == (4, 3)
        np.testing.assert_array_equal(ev[0], [1.0, 2.0, 0.0])
        np.testing.assert_array_equal(ei[2], [0, 2, 4])
        np.testing.assert_array_equal(ev[3], 0.0)


class TestSvxsv:
    @settings(max_examples=40, deadline=None)
    @given(fiber_params, st.integers(min_value=0, max_value=10_000))
    def test_matches_ref(self, p, seed_b):
        dim, k, seed = p
        rng_a = np.random.default_rng(seed)
        rng_b = np.random.default_rng(seed_b)
        a_vals, a_idcs = random_fiber(rng_a, dim, k, int(rng_a.integers(0, min(dim, k) + 1)))
        b_vals, b_idcs = random_fiber(rng_b, dim, k, int(rng_b.integers(0, min(dim, k) + 1)))
        got = intersect.svxsv(
            jnp.array(a_vals), jnp.array(a_idcs), jnp.array(b_vals), jnp.array(b_idcs), dim=dim
        )
        want = ref.svxsv_ref(
            jnp.array(a_vals), jnp.array(a_idcs), jnp.array(b_vals), jnp.array(b_idcs), dim
        )
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-14)

    def test_disjoint_is_zero(self):
        a_vals = jnp.array([1.0, 2.0])
        a_idcs = jnp.array([1, 3], jnp.int32)
        b_vals = jnp.array([4.0, 5.0])
        b_idcs = jnp.array([2, 4], jnp.int32)
        got = intersect.svxsv(a_vals, a_idcs, b_vals, b_idcs, dim=8)
        assert float(got) == 0.0

    def test_identical_patterns(self):
        v = jnp.array([1.0, 2.0, 3.0])
        i = jnp.array([2, 5, 7], jnp.int32)
        got = intersect.svxsv(v, i, v, i, dim=10)
        np.testing.assert_allclose(float(got), 1 + 4 + 9)


class TestSmxsv:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_matches_rowwise_svxsv(self, seed):
        rng = np.random.default_rng(seed)
        n_rows, k, dim = 8, 6, 40
        vals = np.zeros((n_rows, k))
        idcs = np.zeros((n_rows, k), dtype=np.int32)
        for r in range(n_rows):
            w = int(rng.integers(0, k + 1))
            if w:
                idcs[r, :w] = np.sort(rng.choice(dim, size=w, replace=False))
                vals[r, :w] = rng.standard_normal(w)
        b_vals, b_idcs = random_fiber(rng, dim, 10, int(rng.integers(0, 11)))
        got = intersect.smxsv_ell(
            jnp.array(vals), jnp.array(idcs), jnp.array(b_vals), jnp.array(b_idcs), dim=dim
        )
        dense_b = np.zeros(dim)
        np.add.at(dense_b, b_idcs, b_vals)
        want = (vals * dense_b[idcs]).sum(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-14)


class TestSvpsv:
    @settings(max_examples=40, deadline=None)
    @given(fiber_params, st.integers(min_value=0, max_value=10_000))
    def test_matches_ref(self, p, seed_b):
        dim, k, seed = p
        rng_a = np.random.default_rng(seed)
        rng_b = np.random.default_rng(seed_b)
        a_vals, a_idcs = random_fiber(rng_a, dim, k, int(rng_a.integers(0, min(dim, k) + 1)))
        b_vals, b_idcs = random_fiber(rng_b, dim, k, int(rng_b.integers(0, min(dim, k) + 1)))
        got_s, got_m = union_add.svpsv_dense(
            jnp.array(a_vals), jnp.array(a_idcs), jnp.array(b_vals), jnp.array(b_idcs), dim=dim
        )
        want_s, want_m = ref.svpsv_dense_ref(
            jnp.array(a_vals), jnp.array(a_idcs), jnp.array(b_vals), jnp.array(b_idcs), dim
        )
        np.testing.assert_allclose(got_s, want_s, rtol=1e-12, atol=1e-14)
        np.testing.assert_array_equal(np.asarray(got_m), np.asarray(want_m))

    def test_mask_is_union_pattern(self):
        a_vals = jnp.array([1.0, 2.0])
        a_idcs = jnp.array([1, 3], jnp.int32)
        b_vals = jnp.array([4.0, 0.0])  # second entry is padding
        b_idcs = jnp.array([3, 0], jnp.int32)
        s, m = union_add.svpsv_dense(a_vals, a_idcs, b_vals, b_idcs, dim=6)
        np.testing.assert_array_equal(np.asarray(m), [0, 1, 0, 1, 0, 0])
        np.testing.assert_allclose(np.asarray(s), [0, 1, 0, 6, 0, 0])
