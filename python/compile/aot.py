"""AOT compile path: lower every Layer-2 model to HLO **text** plus a
JSON manifest the Rust runtime consumes.

HLO text, NOT jax's serialized StableHLO or HloModuleProto bytes: the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Run once via `make artifacts`; Python never executes on the Rust request
path. Shapes are fixed here and recorded in the manifest — the Rust side
pads its operands to match.
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402

# ---- fixed artifact shapes (recorded in the manifest) -----------------
SPMV_ROWS = 64
SPMV_K = 16
SPMV_COLS = 256
FIBER_K = 64
FIBER_DIM = 512
PR_ROWS = 128
PR_K = 8


def f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def entries():
    """(name, fn, example_args) for every artifact."""
    return [
        (
            "spmv",
            model.spmv_model,
            [f64(SPMV_ROWS, SPMV_K), f64(SPMV_ROWS, SPMV_K), f64(SPMV_COLS)],
            1,
        ),
        (
            "svxdv",
            model.svxdv_model,
            [f64(FIBER_K), f64(FIBER_K), f64(FIBER_DIM)],
            1,
        ),
        (
            "svxsv",
            functools.partial(model.svxsv_model, dim=FIBER_DIM),
            [f64(FIBER_K), f64(FIBER_K), f64(FIBER_K), f64(FIBER_K)],
            1,
        ),
        (
            "smxsv",
            functools.partial(model.smxsv_model, dim=SPMV_COLS),
            [f64(SPMV_ROWS, SPMV_K), f64(SPMV_ROWS, SPMV_K), f64(FIBER_K), f64(FIBER_K)],
            1,
        ),
        (
            "svpsv",
            functools.partial(model.svpsv_model, dim=FIBER_DIM),
            [f64(FIBER_K), f64(FIBER_K), f64(FIBER_K), f64(FIBER_K)],
            2,
        ),
        (
            "pagerank_step",
            model.pagerank_step_model,
            [f64(PR_ROWS, PR_K), f64(PR_ROWS, PR_K), f64(PR_ROWS), f64(1)],
            1,
        ),
        (
            "jacobi_step",
            model.jacobi_step_model,
            [f64(SPMV_ROWS, SPMV_K), f64(SPMV_ROWS, SPMV_K), f64(SPMV_ROWS), f64(SPMV_ROWS), f64(SPMV_ROWS)],
            1,
        ),
    ]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "entries": []}
    for name, fn, example, n_outputs in entries():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        rel = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, rel), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "path": rel,
                "inputs": [list(s.shape) for s in example],
                "n_outputs": n_outputs,
            }
        )
        print(f"  lowered {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
