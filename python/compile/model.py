"""Layer-2 JAX models: the compute graphs built on the Layer-1 Pallas
kernels, AOT-lowered by aot.py into the artifacts the Rust runtime loads
for golden verification and the end-to-end examples.

All entry points are pure functions of f64 arrays with static shapes
(index operands travel as f64 and are cast inside — PJRT parameter
plumbing on the Rust side then only needs one dtype).
"""

import jax
import jax.numpy as jnp

from .kernels import intersect, spmv, union_add

jax.config.update("jax_enable_x64", True)


def _as_idx(x):
    return x.astype(jnp.int32)


def spmv_model(vals, idcs_f, b):
    """ELL SpMV (Fig. 4c / 5a golden model)."""
    return (spmv.spmv_ell(vals, _as_idx(idcs_f), b),)


def svxdv_model(vals, idcs_f, b):
    """Sparse-dense dot product (Fig. 4a golden model)."""
    return (spmv.svxdv(vals, _as_idx(idcs_f), b).reshape((1,)),)


def svxsv_model(a_vals, a_idcs_f, b_vals, b_idcs_f, *, dim):
    """Sparse-sparse dot product (Fig. 4d golden model)."""
    return (
        intersect.svxsv(a_vals, _as_idx(a_idcs_f), b_vals, _as_idx(b_idcs_f), dim=dim).reshape((1,)),
    )


def smxsv_model(vals, idcs_f, b_vals, b_idcs_f, *, dim):
    """sM×sV (Fig. 4f / 5b golden model)."""
    return (
        intersect.smxsv_ell(vals, _as_idx(idcs_f), b_vals, _as_idx(b_idcs_f), dim=dim),
    )


def svpsv_model(a_vals, a_idcs_f, b_vals, b_idcs_f, *, dim):
    """Sparse-sparse addition (Fig. 4e golden model): dense sum + mask."""
    s, m = union_add.svpsv_dense(a_vals, _as_idx(a_idcs_f), b_vals, _as_idx(b_idcs_f), dim=dim)
    return (s, m)


def pagerank_step_model(vals, idcs_f, rank, damping_scalar):
    """One PageRank power-iteration step over a column-normalized ELL
    adjacency matrix (the §3.3 graph workload; examples/pagerank.rs)."""
    idcs = _as_idx(idcs_f)
    n = rank.shape[0]
    contrib = spmv.spmv_ell(vals, idcs, rank)
    d = damping_scalar[0]
    return (d * contrib + (1.0 - d) / n,)


def jacobi_step_model(vals, idcs_f, diag_inv, b, x):
    """One weighted-Jacobi smoothing step x' = x + D^-1 (b - A x)
    (the FEM/iterative-solver workload of §3.3)."""
    ax = spmv.spmv_ell(vals, _as_idx(idcs_f), x)
    return (x + diag_inv * (b - ax),)
