"""Layer-1 Pallas kernel: sparse-sparse dot product — the TPU realization
of SSSR streaming *intersection* (DESIGN.md §Hardware-Adaptation).

The index comparator's insight is that two-sided sparsity reduces to
one-sided indirection once one operand is position-addressable. In VMEM
that is literal: scatter fiber B into a dense VMEM buffer (positions as
addresses), then gather it at fiber A's indices — every matched index
contributes b's value, every unmatched one reads the buffer's zero. This
replaces the comparator's sequential index matching with a vectorized
scatter+gather at the same O(nnz) work.

interpret=True: see spmv.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("dim",))
def svxsv(a_vals, a_idcs, b_vals, b_idcs, *, dim):
    """Sparse-sparse dot product of two padded fibers over dense
    dimension `dim`. Padding: idx 0 / val 0 (contributes 0)."""
    (ka,) = a_vals.shape
    (kb,) = b_vals.shape
    assert a_idcs.shape == (ka,) and b_idcs.shape == (kb,)

    def kernel(a_vals_ref, a_idcs_ref, b_vals_ref, b_idcs_ref, out_ref):
        # scatter B into a dense VMEM-resident buffer...
        dense_b = jnp.zeros((dim,), a_vals_ref.dtype).at[b_idcs_ref[...]].add(b_vals_ref[...])
        # ...and indirect through it with A's indices: the intersection.
        out_ref[0] = jnp.sum(a_vals_ref[...] * dense_b[a_idcs_ref[...]])

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1,), a_vals.dtype),
        interpret=True,
    )(a_vals, a_idcs, b_vals, b_idcs)[0]


@functools.partial(jax.jit, static_argnames=("dim",))
def smxsv_ell(vals, idcs, b_vals, b_idcs, *, dim):
    """sM×sV: ELL matrix (vals/idcs [n, k]) times a sparse vector given
    as a padded fiber; dense [n] result (as the paper's kernel, §3.2.2).
    Scatter once, then gather row-wise."""
    n_rows, _ = vals.shape

    def kernel(vals_ref, idcs_ref, b_vals_ref, b_idcs_ref, out_ref):
        dense_b = jnp.zeros((dim,), vals_ref.dtype).at[b_idcs_ref[...]].add(b_vals_ref[...])
        out_ref[...] = jnp.sum(vals_ref[...] * dense_b[idcs_ref[...]], axis=1)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_rows,), vals.dtype),
        interpret=True,
    )(vals, idcs, b_vals, b_idcs)
