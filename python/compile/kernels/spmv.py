"""Layer-1 Pallas kernel: ELL-padded gather SpMV — the TPU realization of
SSSR streaming *indirection* (DESIGN.md §Hardware-Adaptation).

The paper's ISSR decouples index processing from the FPU so the compute
unit sees a dense operand stream. On a TPU-shaped machine the same
insight maps to: tile rows into VMEM-resident blocks with `BlockSpec`
(the HBM<->VMEM schedule the Snitch cluster expressed with double-
buffered DMA), keep the dense operand resident, and let a vectorized
gather play the ISSR role so the VPU reduction runs on dense data.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom call
the CPU PJRT plugin cannot execute; the interpret path lowers to plain
HLO, which is what the Rust runtime loads (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _spmv_kernel(b_ref, vals_ref, idcs_ref, out_ref):
    """One grid step: rows_block x k_max gather + row reduction.

    b stays fully VMEM-resident (dense operand, like the paper's
    TCDM-resident vector); vals/idcs stream in one row-block per step.
    """
    vals = vals_ref[...]
    idcs = idcs_ref[...]
    b = b_ref[...]
    # the gather is the indirection: b[idcs] with idcs [rows, k]
    gathered = b[idcs]
    out_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spmv_ell(vals, idcs, b, *, block_rows=DEFAULT_BLOCK_ROWS):
    """ELL SpMV: vals/idcs [n_rows, k_max] (padding: idx 0 / val 0),
    b [n_cols] -> out [n_rows]."""
    n_rows, k_max = vals.shape
    assert idcs.shape == (n_rows, k_max)
    assert n_rows % block_rows == 0, "n_rows must be a multiple of block_rows"
    grid = (n_rows // block_rows,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(b.shape, lambda i: tuple(0 for _ in b.shape)),
            pl.BlockSpec((block_rows, k_max), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k_max), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), vals.dtype),
        interpret=True,
    )(b, vals, idcs)


def _svxdv_kernel(vals_ref, idcs_ref, b_ref, out_ref):
    out_ref[0] = jnp.sum(vals_ref[...] * b_ref[...][idcs_ref[...]])


@jax.jit
def svxdv(vals, idcs, b):
    """Sparse-dense dot product on one padded fiber."""
    (k,) = vals.shape
    assert idcs.shape == (k,)
    return pl.pallas_call(
        _svxdv_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), vals.dtype),
        interpret=True,
    )(vals, idcs, b)[0]


def ell_from_csr(ptrs, idcs, vals, k_max=None, pad_rows_to=1):
    """Host-side packing helper (NumPy-level, build path only): convert
    CSR arrays to padded ELL."""
    import numpy as np

    n_rows = len(ptrs) - 1
    widths = [ptrs[r + 1] - ptrs[r] for r in range(n_rows)]
    k = max(widths) if widths else 1
    if k_max is not None:
        assert k <= k_max, f"row width {k} exceeds k_max {k_max}"
        k = k_max
    k = max(k, 1)
    n_pad = ((n_rows + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
    ev = np.zeros((n_pad, k), dtype=np.float64)
    ei = np.zeros((n_pad, k), dtype=np.int32)
    for r in range(n_rows):
        w = widths[r]
        ev[r, :w] = vals[ptrs[r] : ptrs[r + 1]]
        ei[r, :w] = idcs[ptrs[r] : ptrs[r + 1]]
    return ev, ei
