"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

These mirror rust/src/formats/ops.rs one level up: every Pallas kernel is
checked against these references by pytest at build time (the CORE
correctness signal for the compile path), and the Rust simulator is in
turn cross-checked against the AOT artifacts built from the kernels.

All kernels operate on *padded* fixed-shape sparse data (XLA requires
static shapes): an ELL-style (values[n, k], indices[n, k]) layout for
matrices and (values[k], indices[k]) fibers for vectors. Padding entries
use index 0 with value 0 so gathers stay in bounds and contribute
nothing.
"""

import jax.numpy as jnp

__all__ = [
    "spmv_ell_ref",
    "svxdv_ref",
    "svxsv_ref",
    "svpsv_dense_ref",
    "pagerank_step_ref",
    "jacobi_step_ref",
]


def svxdv_ref(vals, idcs, b):
    """Sparse-dense dot product: sum(vals * b[idcs]). Padding entries
    must have vals == 0."""
    return jnp.sum(vals * b[idcs])


def spmv_ell_ref(vals, idcs, b):
    """ELL SpMV: vals/idcs are [n_rows, k_max]; returns [n_rows]."""
    return jnp.sum(vals * b[idcs], axis=1)


def svxsv_ref(a_vals, a_idcs, b_vals, b_idcs, dim):
    """Sparse-sparse dot product via dense scatter (the same
    scatter-then-gather trick the Pallas kernel uses in VMEM)."""
    dense_b = jnp.zeros((dim,), a_vals.dtype).at[b_idcs].add(b_vals)
    return jnp.sum(a_vals * dense_b[a_idcs])


def svpsv_dense_ref(a_vals, a_idcs, b_vals, b_idcs, dim):
    """Sparse-sparse addition, returned as (dense accumulator, mask).

    XLA's static shapes cannot express the dynamic union length, so the
    AOT artifact returns the dense sum plus a nonzero-pattern mask; the
    Rust side re-compresses to a fiber (documented substitution,
    DESIGN.md §Hardware-Adaptation).
    """
    dense = (
        jnp.zeros((dim,), a_vals.dtype).at[a_idcs].add(a_vals).at[b_idcs].add(b_vals)
    )
    mask = (
        jnp.zeros((dim,), a_vals.dtype)
        .at[a_idcs]
        .max(jnp.where(a_vals != 0, 1.0, 0.0))
        .at[b_idcs]
        .max(jnp.where(b_vals != 0, 1.0, 0.0))
    )
    return dense, mask


def pagerank_step_ref(vals, idcs, rank, damping, n_real):
    """One PageRank power iteration on a column-normalized ELL matrix."""
    contrib = spmv_ell_ref(vals, idcs, rank)
    return damping * contrib + (1.0 - damping) / n_real


def jacobi_step_ref(vals, idcs, diag_inv, b, x):
    """One weighted-Jacobi smoothing step: x' = x + D^-1 (b - A x).
    A is ELL (including its diagonal)."""
    ax = spmv_ell_ref(vals, idcs, x)
    return x + diag_inv * (b - ax)
