"""Layer-1 Pallas kernel: sparse-sparse addition — the TPU realization of
SSSR streaming *union* + ESSR writeback (DESIGN.md §Hardware-Adaptation).

The union is a masked dense accumulation in VMEM: both fibers scatter-add
into a zero buffer; the nonzero-pattern mask is accumulated alongside
(the ESSR's joint index stream). XLA's static shapes cannot express the
dynamic result length, so the artifact returns (dense sum, mask) and the
Rust side re-compresses to a fiber.

interpret=True: see spmv.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("dim",))
def svpsv_dense(a_vals, a_idcs, b_vals, b_idcs, *, dim):
    """Union-add of two padded fibers: returns (dense sum [dim],
    pattern mask [dim] with 1.0 where either operand has a nonzero)."""
    (ka,) = a_vals.shape
    (kb,) = b_vals.shape
    assert a_idcs.shape == (ka,) and b_idcs.shape == (kb,)

    def kernel(a_vals_ref, a_idcs_ref, b_vals_ref, b_idcs_ref, sum_ref, mask_ref):
        av, ai = a_vals_ref[...], a_idcs_ref[...]
        bv, bi = b_vals_ref[...], b_idcs_ref[...]
        dense = jnp.zeros((dim,), av.dtype).at[ai].add(av).at[bi].add(bv)
        mask = (
            jnp.zeros((dim,), av.dtype)
            .at[ai]
            .max(jnp.where(av != 0, 1.0, 0.0))
            .at[bi]
            .max(jnp.where(bv != 0, 1.0, 0.0))
        )
        sum_ref[...] = dense
        mask_ref[...] = mask

    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((dim,), a_vals.dtype),
            jax.ShapeDtypeStruct((dim,), a_vals.dtype),
        ),
        interpret=True,
    )(a_vals, a_idcs, b_vals, b_idcs)
